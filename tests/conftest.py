import os

# Tests run on the single real CPU device; ONLY tests that need a mesh spawn
# subprocesses or use the forced-device fixture below (never set the flag
# globally — smoke tests and benches must see 1 device).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running tests (mesh subprocesses, big sweeps)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
