"""KV-cache / SSM-state decode must reproduce the full forward pass exactly
(the core serving invariant) — for every family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as M

ASSIGNED = [a for a in ARCH_IDS if not a.startswith("gpt2")]


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_enc_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_image_tokens, cfg.d_model))

    full, _, _ = M.forward(params, cfg, batch, "train")

    cache = M.init_cache(cfg, B, S, "float32")
    if cfg.family == "audio":
        cache["enc_out"] = M.whisper_encode(params, cfg, batch["frames"])
    step = jax.jit(lambda b, c: M.decode_step(params, cfg, b, c))
    outs = []
    for t in range(S):
        db = {"tokens": toks[:, t:t + 1],
              "pos": jnp.full((B,), t, jnp.int32)}
        if cfg.family == "vlm":
            i = min(t, cfg.n_image_tokens - 1)
            db["image_embeds"] = batch["image_embeds"][:, i:i + 1]
        lg, cache = step(db, cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(dec - full)))
    assert err < 2e-3, (arch, err)


def test_decode_with_ragged_positions():
    """Per-request positions (continuous batching): two requests at different
    positions must match their per-request references."""
    cfg = get_config("llama3.2-3b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    S = 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab)
    full, _, _ = M.forward(params, cfg, {"tokens": toks}, "train")

    cache = M.init_cache(cfg, 2, S, "float32")
    # request 0 advances every tick; request 1 every second tick.  Inactive
    # slots re-decode the same (token, pos) — cache writes are idempotent,
    # so no masking is needed (the engine relies on this).
    pos = [0, 0]
    got = {0: [], 1: []}
    for tick in range(2 * S):
        active = [True, tick % 2 == 0]
        cur = jnp.stack([toks[i, min(pos[i], S - 1)] for i in range(2)])[:, None]
        pvec = jnp.asarray(pos, jnp.int32)
        lg, cache = M.decode_step(params, cfg,
                                  {"tokens": cur, "pos": pvec}, cache)
        for i in range(2):
            if active[i] and pos[i] < S:
                got[i].append(lg[i, 0])
                pos[i] += 1
        if all(p >= S for p in pos):
            break
    for i in range(2):
        dec = jnp.stack(got[i][:S], 0)
        err = float(jnp.max(jnp.abs(dec - full[i])))
        assert err < 2e-3, (i, err)
