"""core/analysis machinery (paper §3 reproduction tools) + SSM oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import analysis
from repro.models import model as M
from repro.models import ssm as S


def small_model():
    cfg = get_config("gpt2-117m").reduced().replace(
        n_layers=4, vocab=256, connection="preln")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    return cfg, params, {"tokens": toks}


def test_cka_identity_and_bounds():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
    assert abs(float(analysis.linear_cka(x, x)) - 1.0) < 1e-5
    y = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    v = float(analysis.linear_cka(x, y))
    assert 0.0 <= v <= 1.0


def test_cka_table_shape():
    cfg, params, batch = small_model()
    out = analysis.cka_table(params, cfg, batch)
    for k in ("mha_out", "mlp_in", "mlp_out"):
        assert len(out[k]) == cfg.n_layers - 1
        assert all(0 <= v <= 1.0 + 1e-6 for v in out[k])


def test_gradient_magnitudes_and_consistency():
    cfg, params, batch = small_model()
    mags = analysis.mha_gradient_magnitudes(params, cfg, batch)
    assert len(mags) == cfg.n_layers
    assert all(m >= 0 and np.isfinite(m) for m in mags)
    # the unrolled capture path must match the scan forward
    rec = analysis.collect_block_activations(params, cfg, batch)
    ref, _, _ = M.forward(params, cfg, batch, "train")
    got = M._logits(params, cfg, rec["final"])
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


def test_ablation_hurts():
    cfg, params, batch = small_model()
    base = analysis.ablate_attention_perplexity(params, cfg, batch)
    no_mha = analysis.ablate_attention_perplexity(params, cfg, batch,
                                                  drop_all_mha=True)
    assert np.isfinite(base) and np.isfinite(no_mha)


# ---------------------------------------------------------------------- #
def _ssd_sequential_ref(x, dt, A, Bm, Cm):
    """O(S) sequential scan oracle for the chunked SSD."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    st = np.zeros((b, h, p, n), np.float64)
    ys = []
    for t in range(s):
        dA = np.exp(np.asarray(dt[:, t] * A[None, :], np.float64))  # (b,h)
        dBx = np.einsum("bh,bn,bhp->bhpn", np.asarray(dt[:, t], np.float64),
                        np.asarray(Bm[:, t], np.float64),
                        np.asarray(x[:, t], np.float64))
        st = st * dA[:, :, None, None] + dBx
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(Cm[:, t], np.float64),
                            st))
    return np.stack(ys, 1), st


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_sequential(chunk):
    b, s, h, p, n = 2, 32, 3, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(9), (b, s, n)) * 0.5
    y, st = S.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_ref, st_ref = _ssd_sequential_ref(x, dt, A, Bm, Cm)
    assert np.max(np.abs(np.asarray(y) - y_ref)) < 1e-3
    assert np.max(np.abs(np.asarray(st) - st_ref)) < 1e-3


def test_ssd_state_carry_across_calls():
    """Prefill-in-two-halves == one call (chunked streaming invariant)."""
    b, s, h, p, n = 1, 16, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    y_full, st_full = S.ssd_chunked(x, dt, A, Bm, Cm, 8)
    y1, st1 = S.ssd_chunked(x[:, :8], dt[:, :8], A, Bm[:, :8], Cm[:, :8], 8)
    y2, st2 = S.ssd_chunked(x[:, 8:], dt[:, 8:], A, Bm[:, 8:], Cm[:, 8:], 8,
                            init_state=st1)
    assert np.max(np.abs(np.asarray(jnp.concatenate([y1, y2], 1))
                         - np.asarray(y_full))) < 1e-4
    assert np.max(np.abs(np.asarray(st2) - np.asarray(st_full))) < 1e-4
