"""benchmarks/hlo_cost.py — the loop-aware HLO analyzer that feeds the
roofline (its correctness underwrites §Roofline)."""
import sys
import os

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import pytest

from benchmarks import hlo_cost, roofline


def test_scan_flops_loop_aware():
    def scanned(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    w = jnp.zeros((8, 128, 128))
    x = jnp.zeros((4, 128))
    txt = jax.jit(scanned).lower(w, x).compile().as_text()
    r = hlo_cost.analyze(txt)
    expected = 8 * 2 * 4 * 128 * 128
    assert abs(r["flops"] - expected) / expected < 0.01
    # XLA's own analysis counts the body once — ours must be ~8x larger
    xla = jax.jit(scanned).lower(w, x).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):        # jax<=0.4.x: per-device list
        xla = xla[0]
    assert r["flops"] > 6 * xla["flops"]


def test_nested_scan_flops():
    def f(w, x):
        def outer(h, wo):
            def inner(hh, wi):
                return hh @ wi, None
            h2, _ = jax.lax.scan(inner, h, wo)
            return h2, None
        h, _ = jax.lax.scan(outer, x, w)
        return h

    w = jnp.zeros((3, 5, 64, 64))
    x = jnp.zeros((2, 64))
    txt = jax.jit(f).lower(w, x).compile().as_text()
    r = hlo_cost.analyze(txt)
    expected = 3 * 5 * 2 * 2 * 64 * 64
    assert abs(r["flops"] - expected) / expected < 0.05


def test_batched_dot_flops():
    f = lambda a, b: jnp.einsum("bij,bjk->bik", a, b)
    a = jnp.zeros((4, 32, 64))
    b = jnp.zeros((4, 64, 16))
    txt = jax.jit(f).lower(a, b).compile().as_text()
    r = hlo_cost.analyze(txt)
    expected = 2 * 4 * 32 * 16 * 64
    assert abs(r["flops"] - expected) / expected < 0.01


def test_roofline_terms_shape():
    f = lambda a, b: jnp.tanh(a @ b)
    a = jnp.zeros((256, 256))
    txt = jax.jit(f).lower(a, a).compile().as_text()
    t = roofline.roofline_terms(txt, model_flops_per_device=2 * 256 ** 3)
    assert t["compute_s"] > 0
    assert t["memory_s"] > 0
    assert t["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert 0.5 < t["useful_fraction"] <= 1.5


def test_param_count_sanity():
    sys.path.insert(0, "src")
    from repro.configs.base import get_config
    total, active = roofline.param_count(get_config("llama3.2-3b"))
    assert 2.0e9 < total < 3.5e9          # ~2.8B non-embedding
    assert total == active
    total, active = roofline.param_count(get_config("deepseek-v3-671b"))
    assert 5.0e11 < total < 8.0e11        # ~650B non-embedding
    assert 2.0e10 < active < 5.0e10       # ~37B active
