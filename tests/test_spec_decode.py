"""Self-speculative decoding on the packed tick (EngineConfig.spec_tokens):
the FAL early-exit draft proposes n-1 tokens per decode lane inside the
engine's ONE jitted dispatch, the full-depth packed forward verifies the
whole proposal as a single length-n segment, and exact-match acceptance
keeps greedy AND seeded token streams bit-identical to non-speculative
decode — across all six connection styles, the dual-branch dispatch,
preemption mid-speculation and prefix-cache hits (rollback never frees
shared pages; the allocator drains fully after every test)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.kernels import ops
from repro.models import model as M
from repro.serve import sampling as SP
from repro.serve.scheduler import EngineConfig, PagedEngine, ServeRequest

SIX_STYLES = ("preln", "parallel", "fal", "falplus", "ablation1", "ablation2")

BASE = EngineConfig(page_size=8, num_pages=48, slots=4, prefill_chunk=8,
                    max_seq=64)
# the reduced test config has 2 layers, so the draft runs block 0 only
SPEC = dataclasses.replace(BASE, spec_tokens=4, draft_blocks=1)


def _cfg_params(conn="fal"):
    cfg = get_config("llama3.2-3b").reduced().replace(connection=conn)
    return cfg, M.init_params(jax.random.PRNGKey(0), cfg)


def _reqs(cfg, n=4, seed=1, temp=0.0):
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        rid=i, prompt=rng.integers(0, cfg.vocab, 4 + i % 7),
        max_new=6 + 3 * (i % 3),
        sampling=SP.SamplingParams(temperature=temp, top_k=50, top_p=0.95,
                                   seed=i))
        for i in range(n)]


def _run(cfg, params, ecfg, reqs):
    eng = PagedEngine(cfg, params, ecfg)
    for r in reqs:
        eng.submit(r)
    done = eng.run(max_ticks=500)
    assert len(done) == len(reqs)
    return {r.rid: list(r.generated) for r in done}, eng


@pytest.mark.parametrize("conn", SIX_STYLES)
def test_spec_identity_styles(conn):
    """Exact-match speculative sampling is LOSSLESS: the spec engine must
    emit bit-identical greedy and seeded token streams to the plain packed
    engine for every connection style, in ONE dispatch per tick."""
    cfg, params = _cfg_params(conn)
    for temp in (0.0, 0.9):
        ref, _ = _run(cfg, params, BASE, _reqs(cfg, temp=temp))
        got, eng = _run(cfg, params, SPEC, _reqs(cfg, temp=temp))
        assert got == ref, (conn, temp)
        st = eng.stats()
        assert st["dispatches_per_tick"] == 1.0, (conn, temp)
        assert st["spec"]["proposals_accepted"] \
            + st["spec"]["proposals_rejected"] > 0
        assert eng.allocator.in_use == 0           # every page drained


def test_spec_matches_dense_oracle():
    """Greedy spec-engine tokens equal the dense full-forward oracle
    token-for-token (the end-to-end losslessness proof: accept-prefix
    verification reproduces sequential decode exactly)."""
    cfg, params = _cfg_params("fal")
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6]) % cfg.vocab
    max_new = 8
    toks = list(prompt)
    for _ in range(max_new):
        lg, _, _ = M.forward(params, cfg,
                             {"tokens": jnp.asarray([toks])}, "train")
        toks.append(int(jnp.argmax(lg[0, -1])))
    oracle = toks[len(prompt):]
    eng = PagedEngine(cfg, params, SPEC)
    eng.submit(ServeRequest(rid=0, prompt=prompt, max_new=max_new))
    assert eng.run()[0].generated == oracle
    assert eng.allocator.in_use == 0


def test_spec_dual_branch():
    """Speculation composes with the dual-branch (MHA||MLP) dispatch:
    same tokens as the sequential non-spec engine."""
    cfg, params = _cfg_params("fal")
    for temp in (0.0, 0.9):
        ref, _ = _run(cfg, params, BASE, _reqs(cfg, temp=temp))
        got, eng = _run(cfg, params,
                        dataclasses.replace(SPEC, dual_branch=True),
                        _reqs(cfg, temp=temp))
        assert eng.plan.dual_branch
        assert got == ref, temp
        assert eng.allocator.in_use == 0


def test_spec_preemption_mid_speculation():
    """Page pressure preempts lanes mid-speculation (rollback + requeue +
    re-prefill); the resumed streams must still equal the unconstrained
    non-spec engine's."""
    cfg, params = _cfg_params("fal")
    ref, _ = _run(cfg, params, BASE, _reqs(cfg, n=10))
    tight = dataclasses.replace(SPEC, num_pages=9)
    got, eng = _run(cfg, params, tight, _reqs(cfg, n=10))
    assert eng.stats()["preemptions"] > 0      # pressure actually preempted
    assert got == ref
    assert eng.allocator.in_use == 0


def test_spec_prefix_cache_rollback_keeps_shared_pages():
    """Spec rollback under prefix sharing: a hit request's rejected growth
    is rewound WITHOUT freeing the shared prefix pages (shrink drops only
    the table's own references), and the emitted stream still matches the
    non-spec prefix-cache engine."""
    cfg, params = _cfg_params("fal")
    pc_base = dataclasses.replace(BASE, prefix_cache=True)
    pc_spec = dataclasses.replace(SPEC, prefix_cache=True)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, 17)

    def reqs():
        # same prompt twice, sequentially: the second admission full-prompt
        # hits the parked prefix and speculates over shared pages
        return [ServeRequest(rid=i, prompt=prompt, max_new=8,
                             sampling=SP.SamplingParams(temperature=0.9,
                                                        top_k=50, seed=7))
                for i in range(2)]

    def run(ecfg):
        eng = PagedEngine(cfg, params, ecfg)
        eng.submit(reqs()[0])
        eng.run()
        eng.submit(reqs()[1])
        done = eng.run()
        return {r.rid: list(r.generated) for r in done}, eng

    ref, _ = run(pc_base)
    got, eng = run(pc_spec)
    assert got == ref
    assert got[0] == got[1]                     # same prompt+seed, same stream
    st = eng.stats()
    assert st["prefix"]["hits"] >= 1
    assert st["spec"]["proposals_accepted"] > 0
    # only the parked tree holds pages now; draining it empties the pool
    assert eng.allocator.in_use == st["prefix"]["cached_pages"]
    eng.pcache.evict(eng.allocator.capacity)
    assert eng.allocator.in_use == 0


def test_spec_one_trace_and_draft_telemetry(monkeypatch):
    """The whole speculative step — n-1 draft iterations + verify — lives
    inside ONE jitted program: the full-depth packed forward traces exactly
    once, the early-exit draft n-1 times (unrolled, same trace), and the
    draft's kernel dispatches surface as '<site>.draft' telemetry rows."""
    cfg, params = _cfg_params("fal")
    verify, draft = [], []
    ov, od = M.paged_decode_step, M.paged_spec_draft

    def cv(params, cfg_, batch, cache, plan=None, **kw):
        verify.append(tuple(batch["tokens"].shape))
        return ov(params, cfg_, batch, cache, plan, **kw)

    def cd(params, cfg_, batch, cache, plan=None, **kw):
        draft.append(tuple(batch["tokens"].shape))
        return od(params, cfg_, batch, cache, plan, **kw)

    monkeypatch.setattr(M, "paged_decode_step", cv)
    monkeypatch.setattr(M, "paged_spec_draft", cd)
    # NOTE: no reset_dispatch_paths() here — the records fire at INNER-jit
    # trace time, and an earlier test in this process may already have
    # traced these shapes (the paths dict is global and monotonic)
    _, eng = _run(cfg, params, SPEC, _reqs(cfg))
    # budget = slots * spec + chunk - 1 = 4*4 + 8 - 1 = 23
    assert verify == [(23,)], verify
    assert draft == [(4,)] * (SPEC.spec_tokens - 1), draft
    paths = ops.dispatch_paths()
    assert "paged_packed_attention" in paths
    assert "paged_packed_attention.draft" in paths
    st = eng.stats()
    assert st["dispatches_per_tick"] == 1.0
    assert st["packed_calls"] == st["ticks"]


def test_spec_acceptance_measured():
    """Seeded sampling shares the draft's fold_in(seed, position) keys, so
    proposals frequently match their targets: the acceptance telemetry
    must show real multi-token ticks (mean emitted length > 1)."""
    cfg, params = _cfg_params("fal")
    _, eng = _run(cfg, params, SPEC, _reqs(cfg, temp=0.9))
    spec = eng.stats()["spec"]
    assert spec["proposals_accepted"] > 0
    assert spec["accepted_len"]["mean"] > 1.0
    assert 0.0 < spec["acceptance_rate"] <= 1.0


def test_spec_config_validation():
    """spec_tokens == 1 (no proposal), out-of-range draft_blocks and a
    budget that can't hold every lane's n-token segment are construction
    errors, not silent misconfigurations."""
    cfg, params = _cfg_params("fal")
    for bad in (dict(spec_tokens=1),
                dict(spec_tokens=4, draft_blocks=0),
                dict(spec_tokens=4, draft_blocks=cfg.n_layers),
                dict(spec_tokens=4, draft_blocks=1, token_budget=7)):
        with pytest.raises(ValueError):
            PagedEngine(cfg, params,
                        dataclasses.replace(BASE, **bad))
    with pytest.raises(ValueError):
        M.paged_spec_draft(params, cfg, {}, {}, draft_blocks=cfg.n_layers)


def test_spec_near_max_seq_falls_back_to_plain_decode():
    """A lane whose full n-token proposal would cross max_seq decodes
    plainly (no variable-length spec segments) and still finishes with
    exactly the non-spec engine's truncated stream."""
    cfg, params = _cfg_params("fal")
    small = dataclasses.replace(BASE, max_seq=24)
    small_spec = dataclasses.replace(SPEC, max_seq=24)
    reqs = lambda: [ServeRequest(rid=0, prompt=np.arange(15) % cfg.vocab,
                                 max_new=20)]
    ref, _ = _run(cfg, params, small, reqs())
    got, eng = _run(cfg, params, small_spec, reqs())
    assert got == ref
    assert eng.finished[0].truncated           # hit the context cap
    assert eng.allocator.in_use == 0
