"""ExecutionPlan (core/plan.py): construction, validation errors,
rejection of the expired legacy-dict shim, and SP-vs-replicated logits
equivalence.

Validation unit tests use a lightweight fake mesh (validate only reads
``axis_names``/``shape``); the equivalence test spawns a subprocess with 2
forced CPU host devices so the rest of the suite keeps seeing 1 device.
"""
import os
import subprocess
import sys
import types

import pytest

from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan, Phase, TPStyle

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def fake_mesh(**axes):
    return types.SimpleNamespace(shape=dict(axes),
                                 axis_names=tuple(axes))


def cfg_for(arch="llama3.2-3b", **kw):
    return get_config(arch).reduced().replace(**kw)


# ------------------------------------------------------------------ build --
def test_single_device_defaults():
    p = ExecutionPlan.single_device()
    assert p.phase is Phase.TRAIN and p.tp is TPStyle.NONE
    assert p.tp_size == 1 and p.tp_axis is None
    assert not p.use_explicit_tp and not p.is_sharded
    p.validate(cfg_for())      # nothing to reject


def test_from_mesh_axes_and_styles():
    mesh = fake_mesh(pod=2, data=4, model=8)
    p = ExecutionPlan.from_mesh(mesh, tp="explicit")
    assert p.data_axes == ("pod", "data") and p.model_axis == "model"
    assert p.tp is TPStyle.EXPLICIT and p.tp_size == 8
    assert p.use_explicit_tp
    # tp_axis only exists INSIDE the shard_map body
    assert p.tp_axis is None
    inner = p.inner()
    assert inner.mesh is None and inner.tp_axis == "model"
    assert inner.tp_size == 8


def test_phase_coercion_and_unknown_phase():
    assert Phase.coerce("train") is Phase.TRAIN
    assert Phase.coerce(Phase.DECODE) is Phase.DECODE
    with pytest.raises(ValueError, match="unknown phase"):
        Phase.coerce("warmup")
    with pytest.raises(ValueError, match="unknown phase"):
        ExecutionPlan.resolve("warmup")
    with pytest.raises(ValueError, match="unknown TP style"):
        TPStyle.coerce("megatron")


def test_with_phase_is_pure():
    p = ExecutionPlan.single_device()
    q = p.with_phase("decode")
    assert q.phase is Phase.DECODE and p.phase is Phase.TRAIN
    assert not q.full_sequence and p.full_sequence


# --------------------------------------------------------------- validate --
def test_validate_bad_divisibility():
    mesh = fake_mesh(model=8)
    plan = ExecutionPlan.from_mesh(mesh, tp="explicit")
    with pytest.raises(ValueError, match="n_heads=6 is not divisible"):
        plan.validate(cfg_for(n_heads=6, n_kv_heads=6))
    with pytest.raises(ValueError, match="n_kv_heads=3 divides neither"):
        plan.validate(cfg_for(n_heads=8, n_kv_heads=3))
    with pytest.raises(ValueError, match="d_ff=100"):
        plan.validate(cfg_for(n_heads=8, n_kv_heads=8, d_ff=100))


def test_validate_family_and_mesh():
    mesh = fake_mesh(data=2, model=4)
    with pytest.raises(ValueError, match="no.*explicit-TP stack"):
        ExecutionPlan.from_mesh(mesh, tp="explicit").validate(
            cfg_for("mamba2-370m"))
    with pytest.raises(ValueError, match="requires a mesh"):
        ExecutionPlan(tp=TPStyle.EXPLICIT).validate(cfg_for())
    with pytest.raises(ValueError, match="model_axis 'tp' not in"):
        ExecutionPlan.from_mesh(mesh, tp="explicit",
                                model_axis="tp").validate(cfg_for())


def test_validate_sp_needs_explicit_tp_and_full_sequence():
    mesh = fake_mesh(model=4)
    with pytest.raises(ValueError, match="requires tp='explicit'"):
        ExecutionPlan.from_mesh(mesh, tp="gspmd", sp=True).validate(cfg_for())
    with pytest.raises(ValueError, match="full-sequence"):
        ExecutionPlan.from_mesh(mesh, tp="explicit", sp=True,
                                phase="decode").validate(cfg_for())
    # the supported combination passes
    ExecutionPlan.from_mesh(mesh, tp="explicit", sp=True).validate(
        cfg_for(n_kv_heads=4))


# ---------------------------------------------------- expired legacy shim --
def test_resolve_rejects_context_dicts():
    """The one-release legacy parallel-ctx dict shim has expired: resolve()
    must fail loudly on a dict (pointing at the replacement), never
    silently coerce it."""
    mesh = fake_mesh(data=2, model=4)
    legacy = {"mesh": mesh, "data_axes": ("data",), "model_axis": "model",
              "tp": "explicit"}
    with pytest.raises(TypeError, match="no longer accepted"):
        ExecutionPlan.resolve(legacy)
    with pytest.raises(TypeError, match="no longer accepted"):
        ExecutionPlan.resolve({})
    assert not hasattr(ExecutionPlan, "from_legacy_dict")
    assert not hasattr(ExecutionPlan, "to_legacy_dict")


# ------------------------------------------- SP == replicated (2 devices) --
def test_sp_logits_match_replicated_two_device_mesh():
    """SP-vs-replicated logits equivalence for preln/fal/falplus on a
    2-device CPU mesh (subprocess keeps the main suite single-device)."""
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan
from repro.models import model as M
mesh = jax.make_mesh((2,), ('model',))
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 500)
for mode in ('preln', 'fal', 'falplus'):
    cfg = get_config('llama3.2-3b').reduced().replace(connection=mode)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b = {'tokens': toks % cfg.vocab}
    ref, _, _ = M.forward(params, cfg, b)
    plan = ExecutionPlan.from_mesh(mesh, tp='explicit', sp=True).validate(cfg)
    with mesh:
        y, _, _ = jax.jit(lambda p, b: M.forward(p, cfg, b, plan))(params, b)
    err = float(np.max(np.abs(np.asarray(y) - np.asarray(ref))))
    assert err < 5e-4, (mode, err)
print('OK')
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
