"""Dual-branch (MHA||MLP) decode: bit-exact logits equivalence vs the
sequential path across connection modes and decoder families, loud
``ExecutionPlan.validate`` errors for modes/phases where the branches cannot
run concurrently, the fused Pallas dispatch vs its oracle, and the
structural no-extra-collectives gate under explicit TP."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import fal
from repro.core.plan import ExecutionPlan, Phase
from repro.models import model as M
from repro.serve.paged_cache import pages_needed
from repro.serve.scheduler import EngineConfig, PagedEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the six styles split exactly on fal.mlp_input_depends_on_local_attention
DUAL_MODES = ("fal", "parallel", "ablation2")
SEQ_ONLY_MODES = ("preln", "falplus", "ablation1")

FAMILY_ARCHS = [("llama3.2-3b", "dense"),
                ("qwen3-moe-30b-a3b", "moe"),
                ("llava-next-mistral-7b", "vlm")]


def _paged_logits(cfg, params, toks, chunk, *, dual, page_size=8,
                  num_pages=24):
    """Drive paged_decode_step over ``toks`` in chunks under a paged plan
    with/without dual_branch; return all logits."""
    B, S = toks.shape
    T = pages_needed(S, page_size)
    plan = ExecutionPlan.single_device(Phase.PAGED, dual_branch=dual)
    cache = M.init_paged_cache(cfg, num_pages, page_size, B, "float32")
    bt = jnp.asarray(np.arange(1, 1 + B * T, dtype=np.int32).reshape(B, T))
    step = jax.jit(lambda b, c: M.paged_decode_step(params, cfg, b, c, plan))
    outs, t = [], 0
    while t < S:
        nv = min(chunk, S - t)
        padded = np.zeros((B, chunk), np.int32)
        padded[:, :nv] = np.asarray(toks[:, t:t + nv])
        lg, cache = step({"tokens": jnp.asarray(padded),
                          "pos": jnp.full((B,), t, jnp.int32),
                          "n_valid": jnp.full((B,), nv, jnp.int32),
                          "block_tables": bt}, cache)
        outs.append(lg[:, :nv])
        t += nv
    return jnp.concatenate(outs, 1)


@pytest.mark.parametrize("arch,family", FAMILY_ARCHS)
@pytest.mark.parametrize("mode", DUAL_MODES)
def test_dual_branch_bit_exact_paged(arch, family, mode):
    """Dual-branch paged decode must be BIT-IDENTICAL to sequential decode
    (same primitives, same operands, same residual-merge association) for
    every dual-eligible style x decoder family."""
    cfg = get_config(arch).reduced().replace(connection=mode)
    assert cfg.family == family
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    seq = _paged_logits(cfg, params, toks, chunk=1, dual=False)
    dual = _paged_logits(cfg, params, toks, chunk=1, dual=True)
    assert bool(jnp.array_equal(seq, dual)), (
        arch, mode, float(jnp.max(jnp.abs(seq - dual))))


def test_dual_branch_bit_exact_chunked_prefill():
    """Branch parallelism also applies to C > 1 chunked-prefill ticks (the
    signal is then the fresh per-position export, not the per-slot cache)."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 21), 0, cfg.vocab)
    for chunk in (5, 21):
        seq = _paged_logits(cfg, params, toks, chunk=chunk, dual=False)
        dual = _paged_logits(cfg, params, toks, chunk=chunk, dual=True)
        assert bool(jnp.array_equal(seq, dual)), chunk


def test_dual_branch_bit_exact_reduced_cache_dtype():
    """Active lanes must consume this tick's FRESH activation-dtype signal —
    routing it through a bfloat16 KV-cache dtype would round it and break
    bit-identity with the sequential path (regression)."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S, page = 2, 12, 8
    T = pages_needed(S, page)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    bt = jnp.asarray(np.arange(1, 1 + B * T, dtype=np.int32).reshape(B, T))

    def drive(dual):
        plan = ExecutionPlan.single_device(Phase.PAGED, dual_branch=dual)
        cache = M.init_paged_cache(cfg, 24, page, B, "bfloat16")
        step = jax.jit(
            lambda b, c: M.paged_decode_step(params, cfg, b, c, plan))
        outs = []
        for t in range(S):
            lg, cache = step({"tokens": toks[:, t:t + 1],
                              "pos": jnp.full((B,), t, jnp.int32),
                              "n_valid": jnp.ones((B,), jnp.int32),
                              "block_tables": bt}, cache)
            outs.append(lg)
        return jnp.concatenate(outs, 1)

    assert bool(jnp.array_equal(drive(False), drive(True)))


def test_dual_branch_bit_exact_contiguous_decode():
    """decode_step (contiguous KV cache) honors plan.dual_branch too."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)

    def drive(dual):
        plan = ExecutionPlan.single_device(Phase.DECODE, dual_branch=dual)
        cache = M.init_cache(cfg, 2, 10, "float32")
        step = jax.jit(
            lambda b, c: M.decode_step(params, cfg, b, c, plan))
        outs = []
        for t in range(10):
            lg, cache = step({"tokens": toks[:, t:t + 1],
                              "pos": jnp.full((2,), t, jnp.int32)}, cache)
            outs.append(lg)
        return jnp.concatenate(outs, 1)

    assert bool(jnp.array_equal(drive(False), drive(True)))


def test_dual_branch_mla_paged():
    """MLA (latent pages) has no fused kernel but still runs branch-parallel
    dispatch; bit-exactness must hold there as well."""
    cfg = get_config("deepseek-v3-671b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)
    seq = _paged_logits(cfg, params, toks, chunk=1, dual=False)
    dual = _paged_logits(cfg, params, toks, chunk=1, dual=True)
    assert bool(jnp.array_equal(seq, dual))


# --------------------------------------------------------------------------- #
# validation
# --------------------------------------------------------------------------- #
def test_dual_branch_modes_predicate():
    assert set(fal.DUAL_BRANCH_MODES) == set(DUAL_MODES)
    for m in SEQ_ONLY_MODES:
        assert fal.mlp_input_depends_on_local_attention(m)


@pytest.mark.parametrize("mode", SEQ_ONLY_MODES)
def test_validate_rejects_sequential_only_modes(mode):
    cfg = get_config("llama3.2-3b").reduced().replace(connection=mode)
    plan = ExecutionPlan.single_device(Phase.DECODE, dual_branch=True)
    with pytest.raises(ValueError, match="must assemble MHA"):
        plan.validate(cfg)


def test_validate_rejects_full_sequence_phases():
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    for phase in ("train", "eval", "prefill"):
        plan = ExecutionPlan.single_device(phase, dual_branch=True)
        with pytest.raises(ValueError, match="decode-time dispatch"):
            plan.validate(cfg)
    # forward() validates, so a dual plan can never run full-sequence blocks
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    with pytest.raises(ValueError, match="decode-time dispatch"):
        M.forward(params, cfg, {"tokens": toks},
                  ExecutionPlan.single_device(dual_branch=True))


@pytest.mark.parametrize("arch", ["whisper-small", "mamba2-370m"])
def test_validate_rejects_families_without_dual_dispatch(arch):
    """audio decoder blocks consume cross-attention, ssm blocks have no
    MHA/MLP fork — reject at validate time, not mid-trace."""
    cfg = get_config(arch).reduced()
    plan = ExecutionPlan.single_device(Phase.DECODE, dual_branch=True)
    with pytest.raises(ValueError, match="has no MHA..MLP decode dispatch"):
        plan.validate(cfg)


def test_dual_branch_bit_exact_hybrid_decode():
    """The zamba weight-shared attention block is a FAL block — dual-branch
    decode applies and stays bit-exact."""
    cfg = get_config("zamba2-1.2b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)

    def drive(dual):
        plan = ExecutionPlan.single_device(Phase.DECODE, dual_branch=dual)
        cache = M.init_cache(cfg, 2, 8, "float32")
        step = jax.jit(lambda b, c: M.decode_step(params, cfg, b, c, plan))
        outs = []
        for t in range(8):
            lg, cache = step({"tokens": toks[:, t:t + 1],
                              "pos": jnp.full((2,), t, jnp.int32)}, cache)
            outs.append(lg)
        return jnp.concatenate(outs, 1)

    assert bool(jnp.array_equal(drive(False), drive(True)))


def test_validate_rejects_post_norms():
    cfg = get_config("gemma2-27b").reduced().replace(connection="parallel")
    plan = ExecutionPlan.single_device(Phase.DECODE, dual_branch=True)
    with pytest.raises(ValueError, match="post_norms"):
        plan.validate(cfg)


def test_engine_rejects_dual_branch_with_preln():
    cfg = get_config("llama3.2-3b").reduced().replace(connection="preln")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="must assemble MHA"):
        PagedEngine(cfg, params, EngineConfig(dual_branch=True))


# --------------------------------------------------------------------------- #
# fused kernel dispatch
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", ["swiglu", "geglu", "gelu"])
def test_fused_dual_branch_kernel_matches_oracle(kind):
    """Interpret-mode fused kernel (paged gather + FFN tiles in one
    pallas_call) vs the gather ref + mlp_apply oracle."""
    from repro.kernels import ops, ref as R
    from repro.models.layers import mlp_apply, mlp_init
    ks = jax.random.split(jax.random.PRNGKey(9), 6)
    B, H, Hkv, D, page, T, Dm, F = 2, 8, 2, 32, 8, 4, 64, 256
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (T * B + 2, page, Hkv, D))
    vp = jax.random.normal(ks[2], (T * B + 2, page, Hkv, D))
    bt = jnp.asarray(np.arange(1, 1 + B * T).reshape(B, T), jnp.int32)
    sl = jnp.asarray([(T - 1) * page + 3, page], jnp.int32)
    x = jax.random.normal(ks[3], (B, 1, Dm))
    ffn = mlp_init(ks[4], Dm, F, kind)
    a, y = ops.dual_branch_decode(q, kp, vp, bt, sl, x, ffn, kind=kind,
                                  interpret=True)
    a_ref = R.paged_attention_ref(q, kp, vp, bt, sl)
    y_ref = mlp_apply(ffn, x, kind)
    assert jnp.max(jnp.abs(a - a_ref)) < 2e-5
    assert jnp.max(jnp.abs(y - y_ref)) < 5e-5


def test_fused_kernel_falls_back_on_non_divisible_dff():
    """d_ff not divisible into Hkv*T tiles -> dispatcher issues the two
    branches separately instead of erroring."""
    from repro.kernels import ops
    from repro.models.layers import mlp_init
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (1, 4, 32))
    kp = jax.random.normal(ks[1], (4, 8, 2, 32))
    vp = jax.random.normal(ks[2], (4, 8, 2, 32))
    bt = jnp.asarray([[1, 2]], jnp.int32)
    sl = jnp.asarray([9], jnp.int32)
    x = jax.random.normal(ks[3], (1, 1, 48))
    ffn = mlp_init(ks[4], 48, 98, "gelu")       # 98 % (Hkv*T = 2*2) != 0
    a, y = ops.dual_branch_decode(q, kp, vp, bt, sl, x, ffn, kind="gelu",
                                  interpret=True)
    assert a.shape == (1, 4, 32) and y.shape == (1, 1, 48)
    # and the separate-branch results still match the oracles
    from repro.kernels import ref as R
    from repro.models.layers import mlp_apply
    assert jnp.max(jnp.abs(a - R.paged_attention_ref(q, kp, vp, bt, sl))) \
        < 2e-5
    assert jnp.max(jnp.abs(y - mlp_apply(ffn, x, "gelu"))) < 5e-5


# --------------------------------------------------------------------------- #
# structural gate: no extra collectives under explicit TP
# --------------------------------------------------------------------------- #
def test_dual_branch_no_extra_collectives_explicit_tp():
    """Lower one steady-state block's paged decode tick under a 2-device
    explicit-TP shard_map with and without dual_branch: both must pay
    exactly ONE all-reduce (the fused MHA+MLP partial-sum assemble) — the
    branch-parallel dispatch adds no collectives.  Subprocess keeps the
    main suite single-device (conftest contract)."""
    script = """
import jax
from repro.core import tp
mesh = jax.make_mesh((2,), ('model',))
counts = tp.assert_dual_no_extra_collectives(mesh, modes=('fal', 'parallel'))
assert set(counts) == {'fal', 'parallel'}
print('OK', counts)
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
