"""Paged serving engine: paged decode == full forward, chunk-width
invariance, FAL-signal caching, preemption->resume determinism, sampling
reproducibility, dual-branch (MHA||MLP) continuous batching, token-PACKED
ticks (one flat (token_budget,) dispatch per engine step serving prefill +
decode lanes together over ragged segments, token streams invariant to the
compiled chunk width AND to a padded (slots*chunk,) reference layout), and
allocator bookkeeping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve import sampling as SP
from repro.serve.paged_cache import BlockTable, PageAllocator, pages_needed
from repro.serve.scheduler import EngineConfig, PagedEngine, ServeRequest


def _paged_logits(cfg, params, toks, chunk, page_size=8, num_pages=24):
    """Drive paged_decode_step over ``toks`` in chunks; return all logits."""
    B, S = toks.shape
    T = pages_needed(S, page_size)
    cache = M.init_paged_cache(cfg, num_pages, page_size, B, "float32")
    bt = jnp.asarray(
        np.arange(1, 1 + B * T, dtype=np.int32).reshape(B, T))
    step = jax.jit(lambda b, c: M.paged_decode_step(params, cfg, b, c))
    outs, t = [], 0
    while t < S:
        nv = min(chunk, S - t)
        padded = np.zeros((B, chunk), np.int32)
        padded[:, :nv] = np.asarray(toks[:, t:t + nv])
        lg, cache = step({"tokens": jnp.asarray(padded),
                          "pos": jnp.full((B,), t, jnp.int32),
                          "n_valid": jnp.full((B,), nv, jnp.int32),
                          "block_tables": bt}, cache)
        outs.append(lg[:, :nv])
        t += nv
    return jnp.concatenate(outs, 1), cache


PAGED_CASES = [("llama3.2-3b", "fal"),        # GQA, rope
               ("deepseek-v3-671b", "fal"),   # MLA latent pages + MoE
               ("gemma2-27b", "falplus"),     # sliding window + softcaps
               ("qwen3-4b", "preln")]         # qk_norm baseline connection


@pytest.mark.parametrize("arch,conn", PAGED_CASES)
def test_paged_decode_matches_forward(arch, conn):
    cfg = get_config(arch).reduced().replace(connection=conn)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _, _ = M.forward(params, cfg, {"tokens": toks}, "train")
    dec, _ = _paged_logits(cfg, params, toks, chunk=5)
    err = float(jnp.max(jnp.abs(dec - full)))
    assert err < 2e-3, (arch, err)


def test_paged_chunk_width_invariance():
    """Chunked prefill must agree with one-token-per-tick paged decode."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 21), 0, cfg.vocab)
    ref, cache1 = _paged_logits(cfg, params, toks, chunk=1)
    for chunk in (4, 7, 21):
        got, _ = _paged_logits(cfg, params, toks, chunk=chunk)
        assert float(jnp.max(jnp.abs(got - ref))) < 1e-3, chunk


def test_fal_signal_cached_per_request():
    """The cache's per-slot a1_sig must be block 1's export at each
    request's last processed position, consistent across tick widths."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    _, c_tok = _paged_logits(cfg, params, toks, chunk=1)
    _, c_chunk = _paged_logits(cfg, params, toks, chunk=16)
    assert float(jnp.max(jnp.abs(c_tok["a1_sig"]))) > 0
    assert float(jnp.max(jnp.abs(c_tok["a1_sig"]
                                 - c_chunk["a1_sig"]))) < 1e-4


# --------------------------------------------------------------------------- #
# engine
# --------------------------------------------------------------------------- #
def _cfg_params():
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    return cfg, M.init_params(jax.random.PRNGKey(0), cfg)


def _reqs(cfg, n=8, seed=1, **kw):
    rng = np.random.default_rng(seed)
    return [ServeRequest(rid=i, prompt=rng.integers(0, cfg.vocab, 4 + i % 7),
                         max_new=6 + 3 * (i % 3), **kw) for i in range(n)]


def test_engine_batched_equals_lone():
    cfg, params = _cfg_params()
    eng = PagedEngine(cfg, params, EngineConfig(
        page_size=8, num_pages=48, slots=4, prefill_chunk=8, max_seq=64))
    for r in _reqs(cfg):
        eng.submit(r)
    done = {r.rid: r for r in eng.run()}
    assert len(done) == 8 and not any(r.truncated for r in done.values())

    probe = done[0]
    lone = PagedEngine(cfg, params, EngineConfig(
        page_size=8, num_pages=48, slots=1, prefill_chunk=8, max_seq=64))
    lone.submit(ServeRequest(rid=0, prompt=probe.prompt,
                             max_new=len(probe.generated)))
    assert lone.run()[0].generated == probe.generated


def test_engine_preemption_resume_deterministic():
    """A page-starved engine must preempt under pressure and still produce
    exactly the tokens of an unconstrained run (requeue -> re-prefill ->
    resume)."""
    cfg, params = _cfg_params()
    outs = {}
    for tag, pages in (("ample", 64), ("tight", 9)):
        eng = PagedEngine(cfg, params, EngineConfig(
            page_size=8, num_pages=pages, slots=4, prefill_chunk=8,
            max_seq=64))
        for r in _reqs(cfg, n=10):
            eng.submit(r)
        done = eng.run()
        assert len(done) == 10
        outs[tag] = ({r.rid: r.generated for r in done},
                     eng.stats()["preemptions"])
    assert outs["tight"][1] > 0          # pressure actually preempted
    assert outs["ample"][1] == 0
    assert outs["ample"][0] == outs["tight"][0]


def test_engine_sampling_reproducible():
    cfg, params = _cfg_params()

    def run_once(seed):
        eng = PagedEngine(cfg, params, EngineConfig(
            page_size=8, num_pages=48, slots=2, prefill_chunk=8, max_seq=64))
        eng.submit(ServeRequest(
            rid=0, prompt=np.arange(6) % cfg.vocab, max_new=10,
            sampling=SP.SamplingParams(temperature=0.8, top_k=50,
                                       top_p=0.95, seed=seed)))
        return eng.run()[0].generated

    a, b, c = run_once(7), run_once(7), run_once(8)
    assert a == b
    assert a != c


def test_engine_rejects_impossible_requests():
    cfg, params = _cfg_params()
    eng = PagedEngine(cfg, params, EngineConfig(
        page_size=8, num_pages=4, slots=2, prefill_chunk=8, max_seq=64))
    eng.submit(ServeRequest(rid=0, prompt=np.zeros(40, np.int64), max_new=4))
    eng.submit(ServeRequest(rid=1, prompt=np.zeros(4, np.int64), max_new=4))
    done = {r.rid: r for r in eng.run()}
    assert done[0].truncated and not done[0].generated   # rejected
    assert len(done[1].generated) == 4                   # small one served
    assert eng.stats()["rejected"] == 1


def test_engine_rejects_prompt_beyond_max_seq():
    """A prompt that can't fit max_seq must be rejected at admission, not
    admitted into an evict-everyone/self-preempt livelock."""
    cfg, params = _cfg_params()
    eng = PagedEngine(cfg, params, EngineConfig(
        page_size=8, num_pages=48, slots=2, prefill_chunk=8, max_seq=24))
    eng.submit(ServeRequest(rid=0, prompt=np.zeros(30, np.int64), max_new=4))
    eng.submit(ServeRequest(rid=1, prompt=np.zeros(6, np.int64), max_new=4))
    done = {r.rid: r for r in eng.run(max_ticks=100)}
    assert done[0].truncated and not done[0].generated
    assert len(done[1].generated) == 4
    assert eng.stats()["preemptions"] == 0


def test_engine_full_admission_reserves_pages():
    """admission='full' holds the worst-case pages at admission, so admitted
    requests are never preempted even when the pool is tight."""
    cfg, params = _cfg_params()
    eng = PagedEngine(cfg, params, EngineConfig(
        page_size=8, num_pages=9, slots=4, prefill_chunk=8, max_seq=64,
        admission="full"))
    for r in _reqs(cfg, n=6):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 6 and not any(r.truncated for r in done)
    assert eng.stats()["preemptions"] == 0


def test_engine_dual_branch_continuous_batching():
    """Dual-branch engine under page pressure: preemption + re-admission
    must keep the per-slot cached FAL signal consistent (re-prefill rebuilds
    it), so resumed requests produce exactly the tokens of an unconstrained
    sequential run."""
    cfg, params = _cfg_params()
    outs = {}
    for tag, dual, pages in (("seq_ample", False, 64),
                             ("dual_ample", True, 64),
                             ("dual_tight", True, 9)):
        eng = PagedEngine(cfg, params, EngineConfig(
            page_size=8, num_pages=pages, slots=4, prefill_chunk=8,
            max_seq=64, dual_branch=dual))
        assert eng.plan.dual_branch is dual
        for r in _reqs(cfg, n=10):
            eng.submit(r)
        done = eng.run()
        assert len(done) == 10 and not any(r.truncated for r in done)
        outs[tag] = ({r.rid: r.generated for r in done},
                     eng.stats()["preemptions"])
    # dual == sequential, tick for tick
    assert outs["dual_ample"][0] == outs["seq_ample"][0]
    # pressure actually preempted and the resumed requests still match
    assert outs["dual_tight"][1] > 0
    assert outs["dual_tight"][0] == outs["seq_ample"][0]


def test_paged_a1_sig_kept_for_inactive_slots():
    """Slots sitting a tick out (n_valid == 0) must keep their cached FAL
    signal instead of having it clobbered by padded-lane garbage."""
    cfg, params = _cfg_params()
    cache = M.init_paged_cache(cfg, 8, 8, 2, "float32")
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    step = jax.jit(lambda b, c: M.paged_decode_step(params, cfg, b, c))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    _, cache = step({"tokens": toks, "pos": jnp.zeros((2,), jnp.int32),
                     "n_valid": jnp.full((2,), 8, jnp.int32),
                     "block_tables": bt}, cache)
    before = np.asarray(cache["a1_sig"])
    # decode tick for slot 0 only; slot 1 sits out
    _, cache = step({"tokens": jnp.zeros((2, 1), jnp.int32),
                     "pos": jnp.asarray([8, 8], jnp.int32),
                     "n_valid": jnp.asarray([1, 0], jnp.int32),
                     "block_tables": bt}, cache)
    after = np.asarray(cache["a1_sig"])
    assert not np.allclose(before[0], after[0])   # active slot updated
    assert np.array_equal(before[1], after[1])    # inactive slot untouched


# --------------------------------------------------------------------------- #
# packed ticks: ONE flat (token_budget,) dispatch per engine step
# --------------------------------------------------------------------------- #
SIX_STYLES = ("preln", "parallel", "fal", "falplus", "ablation1", "ablation2")


class _PaddedTickEngine(PagedEngine):
    """Reference engine reproducing the pre-packing padded tick layout:
    every tick dispatches a flat (slots * prefill_chunk,) buffer where lane
    i occupies [i*chunk, (i+1)*chunk) and its unused tail rides as padding
    (tok_pos == -1).  Same tokens as the packed engine, padded FLOPs —
    the baseline the packed layout is measured against (kept OUT of
    src/repro/serve/, which CI greps clean of pad-out)."""

    def _plan_pack(self):
        from repro.serve.scheduler import PackedTick
        S, C = self.ecfg.slots, self.ecfg.prefill_chunk
        tokens = np.zeros((S * C,), np.int32)
        tok_slot = np.repeat(np.arange(S, dtype=np.int32), C)
        tok_pos = np.full((S * C,), -1, np.int32)
        seg_last = np.full((S,), -1, np.int32)
        n_taken = np.zeros((S,), np.int32)
        live = 0
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            rem = r.known()[r.pos:r.pos + C]
            n = len(rem)
            if n == 0:
                continue
            tokens[i * C:i * C + n] = rem
            tok_pos[i * C:i * C + n] = r.pos + np.arange(n)
            seg_last[i] = i * C + n - 1
            n_taken[i] = n
            live += n
        return PackedTick(tokens, tok_slot, tok_pos, seg_last, n_taken, live)


def _engine_tokens(cfg, params, *, num_pages=48, n=6, slots=4,
                   dual=False, chunk=8, cls=PagedEngine, **ecfg_kw):
    eng = cls(cfg, params, EngineConfig(
        page_size=8, num_pages=num_pages, slots=slots, prefill_chunk=chunk,
        max_seq=64, dual_branch=dual, **ecfg_kw))
    for r in _reqs(cfg, n=n):
        eng.submit(r)
    done = eng.run()
    assert len(done) == n
    return {r.rid: r.generated for r in done}, eng


@pytest.mark.parametrize("conn", SIX_STYLES)
def test_packed_tick_chunk_invariance_styles(conn):
    """Token streams must be invariant to the compiled chunk width for
    every connection style — a chunk=1 engine compiles a flat (slots,)
    program (pure token-at-a-time, the seed semantics), a chunk=8 engine
    a (slots + 7,) packed program; both must emit identical tokens with
    exactly one dispatch per tick."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection=conn)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    narrow, _ = _engine_tokens(cfg, params, chunk=1)
    mix, eng = _engine_tokens(cfg, params, chunk=8)
    assert mix == narrow, conn
    st = eng.stats()
    assert st["dispatches"] == st["ticks"] == st["packed_calls"]
    assert st["dispatches_per_tick"] == 1.0


@pytest.mark.parametrize("conn", SIX_STYLES)
def test_packed_tick_matches_padded_baseline(conn):
    """The tentpole identity: the packed (token_budget,) engine must emit
    exactly the tokens of the padded (slots*chunk,) reference layout for
    every connection style, while burning a fraction of its padding."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection=conn)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    packed, ep = _engine_tokens(cfg, params, chunk=8)
    padded, eb = _engine_tokens(cfg, params, chunk=8, cls=_PaddedTickEngine)
    assert packed == padded, conn
    sp, sb = ep.stats(), eb.stats()
    assert sp["dispatches_per_tick"] == sb["dispatches_per_tick"] == 1.0
    # packed budget (slots + chunk - 1 = 11) vs padded rectangle (32)
    assert sp["token_budget"] == 11
    assert sp["padding_fraction"]["mean"] < sb["padding_fraction"]["mean"]


def test_packed_tick_matches_padded_baseline_preempt_dual():
    """Packed == padded under page pressure (preemption + re-prefill) with
    the dual-branch dispatch in the loop."""
    cfg, params = _cfg_params()
    packed, ep = _engine_tokens(cfg, params, chunk=8, num_pages=9, n=10,
                                dual=True)
    padded, eb = _engine_tokens(cfg, params, chunk=8, num_pages=9, n=10,
                                dual=True, cls=_PaddedTickEngine)
    assert ep.stats()["preemptions"] > 0
    assert packed == padded


def test_packed_tick_token_budget_and_fairness():
    """An explicit token_budget and the max_prefill_tokens fairness cap
    change pacing, never tokens; an infeasible budget (< slots) is
    rejected at construction."""
    cfg, params = _cfg_params()
    base, _ = _engine_tokens(cfg, params, chunk=8)
    wide, _ = _engine_tokens(cfg, params, chunk=8, token_budget=32)
    capped, eng = _engine_tokens(cfg, params, chunk=8, max_prefill_tokens=2)
    assert wide == base and capped == base
    # the cap throttles prefill: at most 2 prefill tokens join any dispatch
    assert eng.stats()["tokens_per_dispatch"]["p99"] <= \
        eng.ecfg.slots + 2
    with pytest.raises(ValueError):
        PagedEngine(cfg, params, EngineConfig(
            page_size=8, num_pages=48, slots=4, prefill_chunk=8,
            token_budget=3, max_seq=64))


@pytest.mark.parametrize("arch,family", [
    ("qwen3-moe-30b-a3b", "moe"),
    ("deepseek-v3-671b", "moe"),           # MLA latent pages ride mixed too
    ("llava-next-mistral-7b", "vlm"),
])
def test_packed_tick_chunk_invariance_families(arch, family):
    """Same engine-level invariant across the decoder families (vlm served
    text-only — the engine's request plumbing contract)."""
    cfg = get_config(arch).reduced().replace(connection="fal")
    if cfg.n_image_tokens:
        cfg = cfg.replace(n_image_tokens=0)
    assert cfg.family == family
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    narrow, _ = _engine_tokens(cfg, params, chunk=1, n=4)
    mix, eng = _engine_tokens(cfg, params, chunk=8, n=4)
    assert mix == narrow, arch
    assert eng.stats()["dispatches_per_tick"] == 1.0


def test_packed_tick_preemption_resume_chunk_invariant():
    """Page pressure under packed ticks: preempted/re-admitted requests must
    still produce exactly the unconstrained chunk=1 engine's tokens
    (position-derived sampling keys + re-prefill make the resume
    deterministic)."""
    cfg, params = _cfg_params()
    narrow, _ = _engine_tokens(cfg, params, chunk=1, num_pages=64, n=10)
    mix, eng = _engine_tokens(cfg, params, chunk=8, num_pages=9, n=10)
    assert eng.stats()["preemptions"] > 0      # pressure actually preempted
    assert eng.stats()["dispatches_per_tick"] == 1.0
    assert mix == narrow


def test_packed_tick_dual_branch_engine():
    """dual_branch composes with packed ticks (branch-parallel at op
    level): same tokens, still one dispatch per tick."""
    cfg, params = _cfg_params()
    seq, _ = _engine_tokens(cfg, params)
    dual, eng = _engine_tokens(cfg, params, dual=True)
    assert eng.plan.dual_branch
    assert eng.stats()["dispatches_per_tick"] == 1.0
    assert dual == seq


def test_packed_tick_compiles_one_program(monkeypatch):
    """The tentpole contract, asserted via trace counting: the engine
    traces its jitted step exactly ONCE — a single flat (token_budget,)
    program serves every tick, whatever mix of phases the lanes are in."""
    cfg, params = _cfg_params()
    traces = []
    orig = M.paged_decode_step

    def counting(params, cfg, batch, cache, plan=None, **kw):
        traces.append(tuple(batch["tokens"].shape))
        return orig(params, cfg, batch, cache, plan, **kw)

    monkeypatch.setattr(M, "paged_decode_step", counting)

    _, eng = _engine_tokens(cfg, params, chunk=8)
    assert traces == [(11,)], traces     # ONE trace: slots + chunk - 1
    st = eng.stats()
    assert st["packed_calls"] == st["ticks"] and st["dispatches_per_tick"] == 1

    traces.clear()
    _engine_tokens(cfg, params, chunk=1)
    assert traces == [(4,)], traces      # narrow engine: ONE program too


def test_pack_tokens_round_robin_reaches_every_lane():
    """Packer-level rotation fairness (deterministic sweep; the hypothesis
    variant lives in test_property.py): under a prefill cap of ``cap``
    tokens per tick, advancing ``rotate`` by one per tick must reach every
    pending prefill lane within ``slots`` ticks — the fixed slot-0 grant
    start starved high-numbered lanes for as long as the pressure
    lasted."""
    from repro.serve.scheduler import pack_tokens
    for S in (1, 2, 4, 6):
        for t0 in (0, 3, 17):
            for cap in (1, 2):
                lists = [list(range(100, 140)) for _ in range(S)]
                advanced = set()
                for t in range(t0, t0 + S):
                    pt = pack_tokens(lists, [0] * S, [False] * S,
                                     budget=max(S, cap), prefill_cap=cap,
                                     rotate=t)
                    advanced |= {i for i in range(S) if pt.n_taken[i] > 0}
                assert advanced == set(range(S)), (S, t0, cap)


def test_packed_tick_prefill_rotation_no_starvation():
    """Round-robin fairness at engine level: with the prefill budget
    squeezed to ONE token per tick, every admitted prefilling lane must
    still advance within ``slots`` ticks (the pre-rotation packer granted
    slot 0 first every tick, starving the last slot for the whole length
    of the earlier prompts)."""
    cfg, params = _cfg_params()
    eng = PagedEngine(cfg, params, EngineConfig(
        page_size=8, num_pages=48, slots=4, prefill_chunk=8, max_seq=64,
        max_prefill_tokens=1))
    for r in _reqs(cfg, n=4):
        eng.submit(r)
    stall = {}
    last_pos = {}
    worst = 0
    while any(s is not None for s in eng.slots) or eng.queue:
        eng.step()
        for r in eng.slots:
            if r is None or r.pos >= len(r.known()) - 1:
                continue                    # decoding/done lanes never starve
            if last_pos.get(r.rid) == r.pos:
                stall[r.rid] = stall.get(r.rid, 0) + 1
                worst = max(worst, stall[r.rid])
            else:
                stall[r.rid] = 0
            last_pos[r.rid] = r.pos
    assert worst < eng.ecfg.slots, worst
    assert len(eng.finished) == 4


def test_packed_step_idle_lane_emits_sentinel():
    """Lanes sitting a tick out (seg_last == -1) must return the -1
    sentinel, never a token sampled from another lane's (or the scratch
    row's) hidden state — the old clamp-to-row-0 gather ran the LM head +
    sampler on garbage and handed back a plausible-looking id."""
    from repro.serve.scheduler import make_packed_step
    cfg, params = _cfg_params()
    step = make_packed_step(cfg)
    cache = M.init_paged_cache(cfg, 8, 8, 2, "float32")
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    tokens = jnp.asarray([5, 6, 7, 0], jnp.int32)
    tok_slot = jnp.zeros((4,), jnp.int32)
    tok_pos = jnp.asarray([0, 1, 2, -1], jnp.int32)
    seg_last = jnp.asarray([2, -1], jnp.int32)      # slot 1 sits out
    z = jnp.zeros((2,), jnp.int32)
    _, nxt, _ = step(params, cache, tokens, tok_slot, tok_pos, bt,
                     seg_last, jnp.zeros((2,)), z, jnp.ones((2,)), z,
                     jnp.asarray([3, 0], jnp.int32))
    nxt = np.asarray(nxt)
    assert nxt[0] >= 0                              # live lane sampled
    assert nxt[1] == -1                             # idle lane: sentinel


def test_packed_tick_occupancy_counts_active_lanes():
    """Occupancy = active lanes / slots per dispatch; a lone request in a
    4-slot engine must report 0.25, full slots report 1.0."""
    cfg, params = _cfg_params()
    eng = PagedEngine(cfg, params, EngineConfig(
        page_size=8, num_pages=48, slots=4, prefill_chunk=8, max_seq=64))
    eng.submit(ServeRequest(rid=0, prompt=np.arange(4) % cfg.vocab,
                            max_new=4))
    eng.run()
    st = eng.stats()
    assert st["mean_occupancy"] == 0.25
    assert st["dispatches_per_tick"] == 1.0


# --------------------------------------------------------------------------- #
# sampler
# --------------------------------------------------------------------------- #
def test_sampler_greedy_and_topk1_match_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(0), (5, 64))
    ref = np.asarray(jnp.argmax(logits, -1))
    B = logits.shape[0]
    z = jnp.zeros((B,), jnp.int32)
    greedy = SP.sample_tokens(logits, jnp.zeros((B,)), z, jnp.ones((B,)),
                              z, z)
    assert np.array_equal(np.asarray(greedy), ref)
    top1 = SP.sample_tokens(logits, jnp.full((B,), 1.0), jnp.ones((B,),
                            jnp.int32), jnp.ones((B,)), z, z)
    assert np.array_equal(np.asarray(top1), ref)


def test_sampler_topk_mask_respected():
    logits = jax.random.normal(jax.random.PRNGKey(1), (4, 128))
    B, k = 4, 5
    topk_sets = np.asarray(jax.lax.top_k(logits, k)[1])
    for seed in range(6):
        toks = np.asarray(SP.sample_tokens(
            logits, jnp.full((B,), 1.5), jnp.full((B,), k, jnp.int32),
            jnp.ones((B,)), jnp.full((B,), seed, jnp.int32),
            jnp.zeros((B,), jnp.int32)))
        for b in range(B):
            assert toks[b] in topk_sets[b]


def test_sampler_topk_exact_on_ties():
    """top-k must keep exactly k candidates even when logits tie at the
    threshold (the old ``>= thr`` mask kept every tied value, silently
    sampling from more than k); ties break toward lower vocab ids (stable
    sort).  Verified against a numpy reference over tied/degenerate
    distributions."""
    from repro.serve.sampling import _mask_top_k
    kept = np.isfinite(np.asarray(_mask_top_k(
        jnp.asarray([1.0, 2.0, 2.0, 2.0, 0.5, 2.0]), jnp.int32(2))))
    assert kept.sum() == 2
    assert list(np.nonzero(kept)[0]) == [1, 2]
    rng = np.random.default_rng(0)
    for _ in range(25):
        V = int(rng.integers(2, 40))
        vals = rng.choice([-1.0, 0.0, 0.25, 1.0, 3.0], size=V)
        k = int(rng.integers(1, V + 1))
        kept = np.isfinite(np.asarray(_mask_top_k(jnp.asarray(vals),
                                                  jnp.int32(k))))
        ref = np.zeros(V, bool)
        ref[np.argsort(-vals, kind="stable")[:k]] = True
        assert kept.sum() == k, (vals, k)
        assert np.array_equal(kept, ref), (vals, k)


def test_sampler_topp_exact_sorted_prefix():
    """top-p keeps the MINIMAL sorted prefix whose exclusive mass is
    below p — tied probabilities past the boundary must not inflate the
    nucleus (four 0.25s at p=0.5 keep exactly two, not four), p == 0
    degenerates to top-1, p >= 1 keeps everything."""
    from repro.serve.sampling import _mask_top_p
    kept = np.isfinite(np.asarray(_mask_top_p(jnp.zeros((4,)),
                                              jnp.float32(0.5))))
    assert kept.sum() == 2
    assert np.isfinite(np.asarray(_mask_top_p(jnp.zeros((4,)),
                                              jnp.float32(0.0)))).sum() == 1
    rng = np.random.default_rng(1)
    for _ in range(25):
        V = int(rng.integers(2, 40))
        logits = rng.choice([0.0, 0.0, 1.0, 2.0], size=V)
        p = float(rng.choice([0.0, 0.3, 0.5, 0.9, 0.999, 1.0]))
        got = np.isfinite(np.asarray(_mask_top_p(jnp.asarray(logits),
                                                 jnp.float32(p))))
        if p >= 1.0:
            ref = np.ones(V, bool)
        else:
            probs = np.exp(logits - logits.max())
            probs = (probs / probs.sum()).astype(np.float32)
            order = np.argsort(-probs, kind="stable")
            keep_sorted = (np.cumsum(probs[order]) - probs[order]) < p
            keep_sorted[0] = True
            ref = np.zeros(V, bool)
            ref[order] = keep_sorted
        assert np.array_equal(got, ref), (logits, p)


def test_sampler_key_is_position_derived():
    """Same (seed, position) -> same draw; different positions -> an
    independent stream (the property preemption-resume determinism rests
    on).  Flat logits make a position-insensitive key collide with ~1/V
    probability per draw."""
    logits = jnp.zeros((1, 256))
    args = (logits, jnp.ones((1,)), jnp.zeros((1,), jnp.int32),
            jnp.ones((1,)), jnp.asarray([3], jnp.int32))
    t5a = SP.sample_tokens(*args, jnp.asarray([5], jnp.int32))
    t5b = SP.sample_tokens(*args, jnp.asarray([5], jnp.int32))
    assert int(t5a[0]) == int(t5b[0])
    draws = {int(SP.sample_tokens(*args, jnp.asarray([p], jnp.int32))[0])
             for p in range(5, 13)}
    assert len(draws) > 1                    # pos actually enters the key


def test_fast_sampler_bit_equal_to_reference():
    """The partial-top-k fast sampler must be a BIT-EXACT drop-in for the
    reference ``sample_one`` on every eligible lane (greedy, or
    ``1 <= top_k <= TOPK_FAST_CAP``), across top-p values including the
    degenerate p == 0 / p >= 1 ends, temperatures, seeds and positions.
    Exactness is what lets the engine pick the variant per tick without
    perturbing seeded streams."""
    V = 512
    fast = jax.jit(jax.vmap(SP.fast_sampler(V)))
    ref = SP.sample_tokens
    rng = np.random.default_rng(7)
    for case in range(8):
        # tie-heavy logits stress the stable-order guarantee
        logits = jnp.asarray(rng.choice(
            [-2.0, 0.0, 0.0, 0.5, 1.0, 3.0], size=(6, V)).astype(np.float32))
        for k in (1, 5, 50, SP.TOPK_FAST_CAP):
            for p in (0.0, 0.3, 0.95, 1.0):
                for temp in (0.0, 0.7, 1.5):
                    B = logits.shape[0]
                    args = (logits, jnp.full((B,), temp),
                            jnp.full((B,), k, jnp.int32), jnp.full((B,), p),
                            jnp.arange(B, dtype=jnp.int32) + case,
                            jnp.arange(B, dtype=jnp.int32) * 3)
                    assert np.array_equal(np.asarray(fast(*args)),
                                          np.asarray(ref(*args))), \
                        (case, k, p, temp)


def test_fast_sampler_eligibility():
    """Greedy lanes are always eligible; seeded lanes only when top_k is
    active and within the cap (top_k disabled or above the cap needs the
    full-vocab reference masks)."""
    V = 2048
    assert SP.fast_eligible(SP.SamplingParams(), V)
    assert SP.fast_eligible(SP.SamplingParams(temperature=0.9, top_k=50), V)
    assert SP.fast_eligible(
        SP.SamplingParams(temperature=0.9, top_k=SP.TOPK_FAST_CAP), V)
    assert not SP.fast_eligible(
        SP.SamplingParams(temperature=0.9, top_k=SP.TOPK_FAST_CAP + 1), V)
    assert not SP.fast_eligible(SP.SamplingParams(temperature=0.9, top_k=0), V)
    assert not SP.fast_eligible(
        SP.SamplingParams(temperature=0.9, top_k=0, top_p=0.9), V)


def test_engine_reference_fallback_above_cap_reproducible():
    """A lane with top_k above the fast cap forces the reference program
    for that tick; streams stay deterministic and the tick still costs one
    dispatch."""
    cfg, params = _cfg_params()
    ecfg = EngineConfig(page_size=8, num_pages=48, slots=4, prefill_chunk=8,
                        max_seq=64)

    def run():
        eng = PagedEngine(cfg, params, ecfg)
        for i in range(3):
            eng.submit(ServeRequest(
                rid=i, prompt=(np.arange(5) + i) % cfg.vocab, max_new=6,
                sampling=SP.SamplingParams(temperature=0.8,
                                        top_k=SP.TOPK_FAST_CAP + 40,
                                        top_p=0.95, seed=i)))
        done = eng.run()
        return {d.rid: d.generated for d in done}, eng.stats()

    a, st = run()
    b, _ = run()
    assert a == b
    assert st["dispatches_per_tick"] == 1.0


# --------------------------------------------------------------------------- #
# allocator / block tables
# --------------------------------------------------------------------------- #
def test_page_allocator_bookkeeping():
    a = PageAllocator(num_pages=8, page_size=4)
    assert a.capacity == 7                       # page 0 is scratch
    got = a.alloc(3)
    assert got is not None and 0 not in got
    assert a.in_use == 3 and a.alloc(5) is None  # all-or-nothing
    assert a.in_use == 3                         # failed alloc took nothing
    a.free(got[:1])
    st = a.stats()
    assert st["allocs"] == 3 and st["frees"] == 1 and st["in_use"] == 2
    assert st["peak_in_use"] == 3


def test_block_table_growth_and_fragmentation():
    a = PageAllocator(num_pages=16, page_size=4)
    t = BlockTable(a, max_blocks=8)
    assert t.ensure(1) and len(t.pages) == 1
    assert t.ensure(4) and len(t.pages) == 1     # same page still covers
    assert t.ensure(5) and len(t.pages) == 2
    assert t.internal_fragmentation(5) == 3
    row = t.as_row()
    assert row.shape == (8,) and list(row[:2]) == t.pages
    assert not t.ensure(100)                     # beyond max_blocks
    t.release()
    assert a.in_use == 0 and t.pages == []
    assert pages_needed(0, 4) == 0 and pages_needed(9, 4) == 3
