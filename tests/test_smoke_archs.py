"""Per-architecture smoke tests: reduced variant of each assigned arch runs a
forward + one train step on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as M
from repro.optim import adamw
from repro.train import step as tstep

ASSIGNED = [a for a in ARCH_IDS if not a.startswith("gpt2")]


def make_batch(cfg, B=2, S=64, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (B, S),
                                          0, cfg.vocab)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.n_enc_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.n_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux, _ = M.forward(params, cfg, batch, "train")
    assert logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    ocfg = adamw.AdamWConfig(lr=1e-3)
    state = tstep.init_state(jax.random.PRNGKey(0), cfg, ocfg)
    step = jax.jit(tstep.make_train_step(cfg, ocfg))
    batch = make_batch(cfg)
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    diff = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))),
                     state["params"], state2["params"]))
    assert diff > 0


@pytest.mark.parametrize("conn", ["preln", "parallel", "fal", "falplus",
                                  "ablation1", "ablation2"])
def test_connection_modes_dense(conn):
    cfg = get_config("llama3.2-3b").reduced().replace(connection=conn)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    loss, _ = M.loss_fn(params, cfg, make_batch(cfg))
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("conn", ["fal", "falplus"])
@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "whisper-small",
                                  "zamba2-1.2b", "gemma2-27b"])
def test_connection_modes_nondense(arch, conn):
    cfg = get_config(arch).reduced().replace(connection=conn)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    loss, _ = M.loss_fn(params, cfg, make_batch(cfg))
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_grads_finite(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    g = jax.grad(lambda p: M.loss_fn(p, cfg, make_batch(cfg))[0])(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
