"""FAL equation oracle tests: block_apply must implement the paper's
formulas (1), (2), (7) exactly."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core import fal
from repro.models import attention as A
from repro.models import blocks as BL
from repro.models import layers as L


def setup(conn):
    cfg = get_config("llama3.2-3b").reduced().replace(connection=conn)
    k = jax.random.PRNGKey(0)
    p0 = BL.block_init(k, cfg, is_block0=True)
    p1 = BL.block_init(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    return cfg, p0, p1, x, pos


def mha(p, cfg, x, pos):
    return A.gqa_apply(p["attn"], cfg, L.norm_apply(p["ln1"], x, cfg.norm),
                       pos)


def test_preln_eq1():
    cfg, p0, p1, x, pos = setup("preln")
    out, a, _, _ = BL.block_apply(p1, cfg, x, None, pos, 0)
    # eq (1): X + MHA(LN(X)) + MLP(LN(X + MHA(LN(X))))
    a_ref = mha(p1, cfg, x, pos)
    expect = x + a_ref + L.mlp_apply(
        p1["ffn"], L.norm_apply(p1["ln2"], x + a_ref, cfg.norm), cfg.mlp)
    assert jnp.allclose(out, expect, atol=1e-5)
    assert jnp.allclose(a, a_ref, atol=1e-6)


def test_fal_eq2():
    cfg, p0, p1, x, pos = setup("fal")
    # block 1 exports LN(MHA_1(LN(X_1)))
    out0, a1_raw, _, _ = BL.block_apply(p0, cfg, x, None, pos, 0,
                                        is_block0=True)
    a1_ref = mha(p0, cfg, x, pos)
    assert jnp.allclose(a1_raw, a1_ref, atol=1e-6)
    a1n = fal.first_attention_signal(cfg, p0, a1_raw)
    assert jnp.allclose(a1n, L.norm_apply(p0["ln_a"], a1_ref, cfg.norm),
                        atol=1e-6)
    # block 1's own MLP input is LN(X_1) + LN(MHA_1) (footnote 3)
    expect0 = x + a1_ref + L.mlp_apply(
        p0["ffn"],
        L.norm_apply(p0["ln2"], x, cfg.norm) + a1n, cfg.mlp)
    assert jnp.allclose(out0, expect0, atol=1e-5)

    # eq (2) for a later block
    out, _, _, _ = BL.block_apply(p1, cfg, out0, a1n, pos, 0)
    a_i = mha(p1, cfg, out0, pos)
    expect = out0 + a_i + L.mlp_apply(
        p1["ffn"],
        L.norm_apply(p1["ln2"], out0, cfg.norm) + a1n, cfg.mlp)
    assert jnp.allclose(out, expect, atol=1e-5)


def test_falplus_eq7():
    cfg, p0, p1, x, pos = setup("falplus")
    out0, a1_raw, _, _ = BL.block_apply(p0, cfg, x, None, pos, 0,
                                        is_block0=True)
    a1_sig = fal.first_attention_signal(cfg, p0, a1_raw)
    assert jnp.allclose(a1_sig, a1_raw)  # FAL+ exports the raw tensor
    # i = 1 branch: LN(X_1 + MHA_1) only
    a1_ref = mha(p0, cfg, x, pos)
    expect0 = x + a1_ref + L.mlp_apply(
        p0["ffn"], L.norm_apply(p0["ln2"], x + a1_ref, cfg.norm), cfg.mlp)
    assert jnp.allclose(out0, expect0, atol=1e-5)

    # later block: LN(X + MHA_i) + LN_i(MHA_1)
    out, _, _, _ = BL.block_apply(p1, cfg, out0, a1_sig, pos, 0)
    a_i = mha(p1, cfg, out0, pos)
    expect = out0 + a_i + L.mlp_apply(
        p1["ffn"],
        L.norm_apply(p1["ln2"], out0 + a_i, cfg.norm)
        + L.norm_apply(p1["ln_fal"], a1_sig, cfg.norm), cfg.mlp)
    assert jnp.allclose(out, expect, atol=1e-5)


def test_parallel_mode():
    cfg, p0, p1, x, pos = setup("parallel")
    out, _, _, _ = BL.block_apply(p1, cfg, x, None, pos, 0)
    a_ref = mha(p1, cfg, x, pos)
    expect = x + a_ref + L.mlp_apply(
        p1["ffn"], L.norm_apply(p1["ln2"], x, cfg.norm), cfg.mlp)
    assert jnp.allclose(out, expect, atol=1e-5)


def test_mlp_input_dependency_property():
    """The property the TP runtime keys on (core/fal.py)."""
    assert fal.mlp_input_depends_on_local_attention("preln")
    assert fal.mlp_input_depends_on_local_attention("falplus")
    assert not fal.mlp_input_depends_on_local_attention("fal")
    assert not fal.mlp_input_depends_on_local_attention("parallel")


def test_fal_signal_constant_across_depth():
    """The first-attention signal must be the SAME tensor at every depth
    (scan-carried constant): whole-model check via activation capture."""
    from repro.core import analysis
    from repro.models import model as M
    cfg = get_config("llama3.2-3b").reduced().replace(
        connection="fal", n_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    rec = analysis.collect_block_activations(params, cfg, {"tokens": toks})
    a1n = fal.first_attention_signal(cfg, params["block0"],
                                     rec["mha_out"][0])
    # block 1's mlp_in = ln2(x) + a1n  -> recover a1n and compare
    pb = jax.tree.map(lambda a: a[0], params["blocks_dense"])
    recovered = rec["mlp_in"][1] - L.norm_apply(pb["ln2"], rec["x"][1],
                                                cfg.norm)
    assert jnp.allclose(recovered, a1n, atol=1e-5)
