"""Pallas kernel validation: shape/dtype sweeps in interpret mode vs the
pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as FA
from repro.kernels import fused_ln_add as FL
from repro.kernels import ops
from repro.kernels import paged_attention as PA
from repro.kernels import ref as R


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 192, 4, 1, 32),      # MQA, non-pow2 seq
    (2, 64, 4, 4, 128),      # wide head
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, Hkv, D, causal):
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = FA.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                             interpret=True)
    ref = R.attention_ref(q, k, v, causal=causal)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_dtypes(dtype):
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64)).astype(dt)
    k = jax.random.normal(ks[1], (2, 128, 2, 64)).astype(dt)
    v = jax.random.normal(ks[2], (2, 128, 2, 64)).astype(dt)
    out = FA.flash_attention(q, k, v, interpret=True)
    ref = R.attention_ref(q, k, v)
    tol = 2e-5 if dtype == "float32" else 2e-2
    assert jnp.max(jnp.abs(out.astype(jnp.float32)
                           - ref.astype(jnp.float32))) < tol


def test_flash_attention_blockq_invariance():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 4, 64))
    v = jax.random.normal(ks[2], (1, 256, 4, 64))
    outs = [FA.flash_attention(q, k, v, block_q=bq, block_k=bk,
                               interpret=True)
            for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]]
    for o in outs[1:]:
        assert jnp.max(jnp.abs(o - outs[0])) < 1e-5


@pytest.mark.parametrize("shape", [(4, 96, 128), (2, 33, 256), (1, 7, 64)])
@pytest.mark.parametrize("kind", ["rmsnorm", "layernorm"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_fused_ln_add_sweep(shape, kind, dtype):
    dt = jnp.dtype(dtype)
    d = shape[-1]
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    x = jax.random.normal(ks[0], shape).astype(dt)
    a = jax.random.normal(ks[1], shape).astype(dt)
    sc = jax.random.normal(ks[2], (d,))
    bi = jax.random.normal(ks[3], (d,))
    out = FL.fused_ln_add(x, a, sc, bi, kind=kind, block_rows=32,
                          interpret=True)
    ref = R.ln_add_ref(x, a, sc, bi, kind=kind)
    tol = 2e-5 if dtype == "float32" else 5e-2
    assert jnp.max(jnp.abs(out.astype(jnp.float32)
                           - ref.astype(jnp.float32))) < tol


@pytest.mark.parametrize("B,H,Hkv,D,page,T", [
    (2, 4, 4, 32, 8, 4),     # MHA
    (2, 8, 2, 64, 16, 3),    # GQA 4:1
    (1, 4, 1, 32, 8, 5),     # MQA
])
def test_paged_attention_sweep(B, H, Hkv, D, page, T):
    P = T * B + 2
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    q = jax.random.normal(ks[0], (B, H, D))
    k_pages = jax.random.normal(ks[1], (P, page, Hkv, D))
    v_pages = jax.random.normal(ks[2], (P, page, Hkv, D))
    # distinct pages per request, ragged seq_lens incl. a page-boundary case
    bt = jnp.asarray(np.arange(1, 1 + B * T).reshape(B, T), jnp.int32)
    lens = [(T - 1) * page + 3, page, 1][:B]
    sl = jnp.asarray(lens + [5] * (B - len(lens)), jnp.int32)
    out = PA.paged_decode_attention(q, k_pages, v_pages, bt, sl,
                                    interpret=True)
    ref = R.paged_attention_ref(q, k_pages, v_pages, bt, sl)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("C", [1, 4, 16])          # decode / small / chunk
def test_paged_chunk_attention_sweep(C):
    """Chunked (mixed-tick) kernel vs its gather oracle: ragged per-lane
    lengths (a prefilling lane, a decoding lane, an idle lane) at positions
    that straddle page boundaries."""
    B, H, Hkv, D, page, T = 3, 8, 2, 32, 8, 6
    P = T * B + 2
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (B, C, H, D))
    k_pages = jax.random.normal(ks[1], (P, page, Hkv, D))
    v_pages = jax.random.normal(ks[2], (P, page, Hkv, D))
    bt = jnp.asarray(np.arange(1, 1 + B * T).reshape(B, T), jnp.int32)
    # lane 0: full prefill chunk straddling a page boundary; lane 1: decode
    # lane (one valid token) mid-page; lane 2: empty lane with no history
    pos = jnp.asarray([page - 3, 2 * page + 5, 0], jnp.int32)
    nv = jnp.asarray([C, 1, 0], jnp.int32)
    out = PA.paged_chunk_attention(q, k_pages, v_pages, bt, pos, nv,
                                   interpret=True)
    ref = R.paged_chunk_attention_ref(q, k_pages, v_pages, bt, pos, nv)
    assert out.shape == (B, C, H, D)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5
    assert bool(jnp.all(out[2] == 0))            # idle lane emits zeros


def test_paged_chunk_attention_c1_matches_decode_kernel():
    """At C == 1 / n_valid == 1 the chunked kernel must agree with the
    single-token decode kernel contract (seq_lens == pos + 1)."""
    ks = jax.random.split(jax.random.PRNGKey(21), 3)
    B, H, Hkv, D, page, T = 2, 4, 2, 32, 8, 3
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kp = jax.random.normal(ks[1], (B * T + 2, page, Hkv, D))
    vp = jax.random.normal(ks[2], (B * T + 2, page, Hkv, D))
    bt = jnp.asarray(np.arange(1, 1 + B * T).reshape(B, T), jnp.int32)
    pos = jnp.asarray([10, page - 1], jnp.int32)
    one = jnp.ones((B,), jnp.int32)
    chunk = PA.paged_chunk_attention(q, kp, vp, bt, pos, one, interpret=True)
    dec = PA.paged_decode_attention(q[:, 0], kp, vp, bt, pos + 1,
                                    interpret=True)
    assert jnp.max(jnp.abs(chunk[:, 0] - dec)) < 2e-5


@pytest.mark.parametrize("budget", [8, 16])
def test_paged_packed_attention_sweep(budget):
    """Packed ragged kernel vs its gather oracle: one flat token buffer
    holding a prefill segment that straddles a page boundary, a mid-page
    decode segment, and a padding tail (tok_pos == -1)."""
    S, H, Hkv, D, page, T = 3, 8, 2, 32, 8, 6
    P = T * S + 2
    ks = jax.random.split(jax.random.PRNGKey(17), 3)
    q = jax.random.normal(ks[0], (budget, H, D))
    k_pages = jax.random.normal(ks[1], (P, page, Hkv, D))
    v_pages = jax.random.normal(ks[2], (P, page, Hkv, D))
    bt = jnp.asarray(np.arange(1, 1 + S * T).reshape(S, T), jnp.int32)
    # slot 0: 6-token prefill segment crossing the first page boundary;
    # slot 1: decode segment (1 token) mid-page; slot 2 sits out; padding
    # tail belongs to slot 0 but carries tok_pos == -1
    tok_slot = jnp.asarray([0] * 6 + [1] + [0] * (budget - 7), jnp.int32)
    tok_pos = jnp.asarray(list(range(page - 3, page + 3)) + [2 * page + 5]
                          + [-1] * (budget - 7), jnp.int32)
    out = PA.paged_packed_attention(q, k_pages, v_pages, bt, tok_slot,
                                    tok_pos, interpret=True)
    ref = R.paged_packed_attention_ref(q, k_pages, v_pages, bt, tok_slot,
                                       tok_pos)
    assert out.shape == (budget, H, D)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5
    assert bool(jnp.all(out[7:] == 0))           # padding rows emit zeros
    assert bool(jnp.all(ref[7:] == 0))


def test_paged_packed_attention_t_eq_slots_matches_decode_kernel():
    """The all-decode degenerate case (T == slots, one token per slot)
    must agree with the single-token decode kernel contract
    (seq_lens == tok_pos + 1)."""
    ks = jax.random.split(jax.random.PRNGKey(23), 3)
    S, H, Hkv, D, page, T = 2, 4, 2, 32, 8, 3
    q = jax.random.normal(ks[0], (S, H, D))
    kp = jax.random.normal(ks[1], (S * T + 2, page, Hkv, D))
    vp = jax.random.normal(ks[2], (S * T + 2, page, Hkv, D))
    bt = jnp.asarray(np.arange(1, 1 + S * T).reshape(S, T), jnp.int32)
    tok_slot = jnp.arange(S, dtype=jnp.int32)
    tok_pos = jnp.asarray([10, page - 1], jnp.int32)
    packed = PA.paged_packed_attention(q, kp, vp, bt, tok_slot, tok_pos,
                                       interpret=True)
    dec = PA.paged_decode_attention(q, kp, vp, bt, tok_pos + 1,
                                    interpret=True)
    assert jnp.max(jnp.abs(packed - dec)) < 2e-5


def test_paged_packed_attention_ops_dispatch():
    """CPU fallback (gather oracle) == interpret-mode packed kernel."""
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (6, 4, 32))
    k_pages = jax.random.normal(ks[1], (6, 8, 2, 32))
    v_pages = jax.random.normal(ks[2], (6, 8, 2, 32))
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    tok_slot = jnp.asarray([0, 0, 0, 1, 0, 0], jnp.int32)
    tok_pos = jnp.asarray([9, 10, 11, 3, -1, -1], jnp.int32)
    a = ops.paged_packed_attention(q, k_pages, v_pages, bt, tok_slot,
                                   tok_pos, use_pallas=False)
    b = ops.paged_packed_attention(q, k_pages, v_pages, bt, tok_slot,
                                   tok_pos, interpret=True)
    assert jnp.max(jnp.abs(a - b)) < 2e-5


def test_paged_chunk_attention_ops_dispatch():
    """CPU fallback (gather oracle) == interpret-mode chunked kernel."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (2, 4, 4, 32))
    k_pages = jax.random.normal(ks[1], (6, 8, 2, 32))
    v_pages = jax.random.normal(ks[2], (6, 8, 2, 32))
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    pos = jnp.asarray([9, 3], jnp.int32)
    nv = jnp.asarray([4, 2], jnp.int32)
    a = ops.paged_chunk_attention(q, k_pages, v_pages, bt, pos, nv,
                                  use_pallas=False)
    b = ops.paged_chunk_attention(q, k_pages, v_pages, bt, pos, nv,
                                  interpret=True)
    assert jnp.max(jnp.abs(a - b)) < 2e-5


def test_paged_attention_ops_dispatch():
    """CPU fallback (gather ref) == interpret-mode kernel."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 4, 32))
    k_pages = jax.random.normal(ks[1], (6, 8, 2, 32))
    v_pages = jax.random.normal(ks[2], (6, 8, 2, 32))
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    sl = jnp.asarray([11, 7], jnp.int32)
    a = ops.paged_decode_attention(q, k_pages, v_pages, bt, sl,
                                   use_pallas=False)
    b = ops.paged_decode_attention(q, k_pages, v_pages, bt, sl,
                                   interpret=True)
    assert jnp.max(jnp.abs(a - b)) < 2e-5


def test_ops_dispatch_matches_model_attention():
    """kernels.ops CPU fallback == models.attention blockwise =="""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    a = ops.flash_attention(q, k, v, use_pallas=False)
    b = R.attention_ref(q, k, v)
    assert jnp.max(jnp.abs(a - b)) < 1e-5
    c = ops.flash_attention(q, k, v, interpret=True)
    assert jnp.max(jnp.abs(c - b)) < 1e-5
