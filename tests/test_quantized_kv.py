"""Quantized KV pages (EngineConfig.kv_dtype): per-page-row scale
correctness against the fp32 oracle, COW scale preservation, and
engine-level greedy/prefix-hit token identity across connection styles.

The format under test: int8/fp8 K/V pools (P, page, Hkv, Dh) plus
(P, page) fp32 ``k_scale``/``v_scale`` pools — ONE scale per cached token
row, shared across KV heads, history-free (a row's scale depends only on
that row's values), so COW page copies and prefix-cache shares stay
bit-exact and idempotent.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.kernels import ops, ref
from repro.kernels import paged_attention as PA
from repro.models import attention as A
from repro.models import model as M
from repro.serve import sampling as SP
from repro.serve.scheduler import EngineConfig, PagedEngine, ServeRequest

SIX_STYLES = ("preln", "parallel", "fal", "falplus", "ablation1",
              "ablation2")


# --------------------------------------------------------------------------- #
# quantize / dequantize round trip
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("store,bound", [(jnp.int8, 0.008),
                                         (jnp.float8_e4m3fn, 0.07)])
def test_quant_rows_roundtrip_bounded(store, bound):
    """Per-token-row quantization: dequantized rows land within the grid's
    relative error bound of the originals (int8: amax/127 grid -> half a
    step is ~0.4% of amax; fp8 e4m3: ~4% relative)."""
    vals = jax.random.normal(jax.random.PRNGKey(0), (6, 5, 4, 32))
    q, s = A._quant_rows(vals, store)
    deq = q.astype(store).astype(jnp.float32) * s[..., None, None]
    amax = jnp.max(jnp.abs(vals), axis=(-2, -1), keepdims=True)
    rel = jnp.max(jnp.abs(deq - vals) / amax)
    assert float(rel) < bound, float(rel)
    # history-free: re-quantizing the dequantized values is a fixed point
    # in scale (same amax row -> same scale) for int8's exact grid
    if store == jnp.int8:
        q2, s2 = A._quant_rows(deq, store)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s), rtol=1e-6)


def test_quantized_oracle_matches_manual_dequant():
    """The paged oracles' in-gather dequant == gather-then-multiply by
    hand: the scale application point cannot drift."""
    key = jax.random.PRNGKey(1)
    P, page, Hkv, Dh, H, B, T = 12, 8, 2, 16, 4, 2, 3
    ks = jax.random.split(key, 6)
    kp = jax.random.randint(ks[0], (P, page, Hkv, Dh), -127, 128, jnp.int8)
    vp = jax.random.randint(ks[1], (P, page, Hkv, Dh), -127, 128, jnp.int8)
    ksc = jax.random.uniform(ks[2], (P, page), minval=0.005, maxval=0.05)
    vsc = jax.random.uniform(ks[3], (P, page), minval=0.005, maxval=0.05)
    bt = jnp.arange(1, 1 + B * T).reshape(B, T)
    q = jax.random.normal(ks[4], (B, H, Dh))
    seq = jnp.array([9, 20])

    def dq(pages, sc):
        return (pages.astype(jnp.float32)
                * sc[:, :, None, None]).astype(jnp.float32)

    got = ref.paged_attention_ref(q, kp, vp, bt, seq, k_scale=ksc,
                                  v_scale=vsc)
    want = ref.paged_attention_ref(q, dq(kp, ksc), dq(vp, vsc), bt, seq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("store", ["int8", "fp8"])
def test_quantized_kernels_match_oracles_interpret(store):
    """All three paged Pallas kernels dequantize in the DMA-to-VMEM step:
    interpret-mode outputs match the gather-based oracles."""
    key = jax.random.PRNGKey(2)
    P, page, Hkv, Dh, H = 16, 16, 4, 32, 8
    B, T = 3, 4
    ks = jax.random.split(key, 8)
    if store == "int8":
        kp = jax.random.randint(ks[0], (P, page, Hkv, Dh), -120, 120,
                                jnp.int8)
        vp = jax.random.randint(ks[1], (P, page, Hkv, Dh), -120, 120,
                                jnp.int8)
    else:
        kp = jax.random.normal(ks[0], (P, page, Hkv, Dh)).astype(
            jnp.float8_e4m3fn)
        vp = jax.random.normal(ks[1], (P, page, Hkv, Dh)).astype(
            jnp.float8_e4m3fn)
    ksc = jax.random.uniform(ks[2], (P, page), minval=0.005, maxval=0.02)
    vsc = jax.random.uniform(ks[3], (P, page), minval=0.005, maxval=0.02)
    bt = jax.random.permutation(ks[4], jnp.arange(1, P))[:B * T].reshape(B, T)

    q = jax.random.normal(ks[5], (B, H, Dh))
    seq = jnp.array([17, 33, 64])
    np.testing.assert_allclose(
        np.asarray(PA.paged_decode_attention(q, kp, vp, bt, seq,
                                             k_scale=ksc, v_scale=vsc,
                                             interpret=True)),
        np.asarray(ref.paged_attention_ref(q, kp, vp, bt, seq, k_scale=ksc,
                                           v_scale=vsc)), atol=2e-5)

    C = 4
    qc = jax.random.normal(ks[6], (B, C, H, Dh))
    pos = jnp.array([5, 17, 40])
    nv = jnp.array([4, 1, 2])
    np.testing.assert_allclose(
        np.asarray(PA.paged_chunk_attention(qc, kp, vp, bt, pos, nv,
                                            k_scale=ksc, v_scale=vsc,
                                            interpret=True)),
        np.asarray(ref.paged_chunk_attention_ref(qc, kp, vp, bt, pos, nv,
                                                 k_scale=ksc,
                                                 v_scale=vsc)), atol=2e-5)

    Tt = 8
    qt = jax.random.normal(ks[7], (Tt, H, Dh))
    tok_slot = jnp.array([0, 0, 1, 2, 2, 2, 0, 0], jnp.int32)
    tok_pos = jnp.array([5, 6, 17, 40, 41, 42, -1, -1], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(PA.paged_packed_attention(qt, kp, vp, bt, tok_slot,
                                             tok_pos, k_scale=ksc,
                                             v_scale=vsc, interpret=True)),
        np.asarray(ref.paged_packed_attention_ref(qt, kp, vp, bt, tok_slot,
                                                  tok_pos, k_scale=ksc,
                                                  v_scale=vsc)), atol=2e-5)


def test_quantized_logit_error_bounded():
    """End-to-end accuracy: a quantized int8 paged forward's logits land
    within a bounded max-abs error of the unquantized engine's on the same
    tokens (the kv_dtype knob trades bounded logit error for HBM)."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 1, cfg.vocab)
    batch = dict(tokens=toks.astype(jnp.int32)[0][None],
                 pos=jnp.array([0]), n_valid=jnp.array([12]),
                 block_tables=jnp.array([[1, 2]], jnp.int32))
    out = {}
    for kv in ("", "int8"):
        cache = M.init_paged_cache(cfg, 8, 8, 1, "float32", kv_dtype=kv)
        logits, _ = M.paged_decode_step(params, cfg, dict(batch), cache)
        out[kv] = np.asarray(logits)
    err = np.max(np.abs(out["int8"] - out[""]))
    ref_mag = np.max(np.abs(out[""]))
    assert err < 0.05 * ref_mag, (err, ref_mag)


# --------------------------------------------------------------------------- #
# cache structure + COW
# --------------------------------------------------------------------------- #
def test_init_paged_cache_kv_dtypes():
    cfg = get_config("llama3.2-3b").reduced()
    for kv, dt, scaled in (("", "float32", False), ("bf16", "bfloat16",
                                                    False),
                           ("int8", "int8", True),
                           ("fp8", "float8_e4m3fn", True)):
        c = M.init_paged_cache(cfg, 8, 8, 2, "float32", kv_dtype=kv)
        assert str(c["block0"]["k"].dtype) == dt, kv
        assert ("k_scale" in c["block0"]) == scaled, kv
        if scaled:
            assert c["block0"]["k_scale"].shape == (8, 8)
            assert c["blocks"]["v_scale"].shape == (cfg.n_layers - 1, 8, 8)
    with pytest.raises(ValueError):
        M.init_paged_cache(cfg, 8, 8, 2, "float32", kv_dtype="int4")


def test_quantized_kv_rejected_for_mla():
    cfg = get_config("deepseek-v3-671b").reduced()
    assert cfg.use_mla
    with pytest.raises(NotImplementedError):
        M.init_paged_cache(cfg, 8, 8, 2, "float32", kv_dtype="int8")


def test_page_copy_preserves_scales_bit_exact():
    """COW over a quantized cache: the (P, page) scale pools ride the same
    page-copy as the K/V pools, and the copied rows are bit-identical."""
    key = jax.random.PRNGKey(4)
    P, page = 10, 8
    sc = jax.random.uniform(key, (P, page), minval=1e-4, maxval=2.0)
    src = jnp.array([2, 5])
    dst = jnp.array([7, 9])
    want = ref.copy_pages_ref(sc, src, dst)
    got = PA.page_copy(sc, src, dst, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.array_equal(np.asarray(got)[np.asarray(dst)],
                          np.asarray(sc)[np.asarray(src)])


def test_copy_paged_pages_quantized_all_layers():
    """model.copy_paged_pages over a quantized cache copies every pool —
    narrow K/V AND fp32 scales — in block0 and the stacked layers, bit
    exactly, and touches no other page."""
    cfg = get_config("llama3.2-3b").reduced()
    cache = M.init_paged_cache(cfg, 8, 8, 2, "float32", kv_dtype="int8")
    k = jax.random.PRNGKey(5)
    cache = jax.tree.map(
        lambda a: jax.random.randint(k, a.shape, -120, 120, jnp.int32)
        .astype(a.dtype) if a.dtype == jnp.int8 else
        jax.random.uniform(k, a.shape, a.dtype)
        if a.dtype == jnp.float32 else a, cache)
    src, dst = jnp.array([2, 3]), jnp.array([5, 6])
    new = M.copy_paged_pages(cache, src, dst)
    for name in ("k", "v", "k_scale", "v_scale"):
        b0, nb0 = np.asarray(cache["block0"][name]), \
            np.asarray(new["block0"][name])
        assert np.array_equal(nb0[np.asarray(dst)], b0[np.asarray(src)]), \
            name
        keep = [p for p in range(8) if p not in (5, 6)]
        assert np.array_equal(nb0[keep], b0[keep]), name
        bs, nbs = np.asarray(cache["blocks"][name]), \
            np.asarray(new["blocks"][name])
        assert np.array_equal(nbs[:, np.asarray(dst)],
                              bs[:, np.asarray(src)]), name


# --------------------------------------------------------------------------- #
# engine-level identity
# --------------------------------------------------------------------------- #
def _req(rid, prompt, max_new=6, greedy=True):
    sp = SP.SamplingParams() if greedy else SP.SamplingParams(
        temperature=0.9, top_k=50, seed=rid)
    return ServeRequest(rid=rid, prompt=np.asarray(prompt, np.int64),
                        max_new=max_new, sampling=sp)


def _run_engine(cfg, params, prompts, **ecfg_kw):
    base = dict(page_size=8, num_pages=48, slots=2, prefill_chunk=8,
                max_seq=64, cache_dtype="float32")
    base.update(ecfg_kw)
    eng = PagedEngine(cfg, params, EngineConfig(**base))
    for i, p in enumerate(prompts):
        eng.submit(_req(i, p))
    eng.run()
    return {r.rid: tuple(r.generated) for r in eng.finished}, eng


def test_quantized_greedy_identity_bench_dims():
    """kv_dtype=int8 greedy token streams == the default engine's at the
    serving bench's model dims.  Cross-dtype argmax identity is a
    workload-level property — random-init logits hit a near-tie the
    storage rounding can flip roughly once per hundred greedy tokens,
    forking the stream — so bench_serving gates exact identity on a
    bounded workload plus measured fidelity floors on the long labels;
    this test pins one verified workload plus the byte-pressure stats
    invariants."""
    cfg = get_config("gpt2-117m").replace(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, d_ff=1024,
        vocab=2048, max_seq=512, dtype="float32", param_dtype="float32",
        remat=False, attn_block_q=64, attn_block_k=128, connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.arange(11, 11 + n) for n in (5, 9, 13)]
    out_ref, eng_ref = _run_engine(cfg, params, prompts, page_size=16,
                                   num_pages=48, max_seq=160)
    out_b16, _ = _run_engine(cfg, params, prompts, page_size=16,
                             num_pages=48, max_seq=160, kv_dtype="bf16")
    out_q, eng_q = _run_engine(cfg, params, prompts, page_size=16,
                               num_pages=48, max_seq=160, kv_dtype="int8")
    assert out_q == out_ref
    assert out_b16 == out_ref
    st = eng_q.stats()["pages"]
    assert st["page_bytes"] > 0
    assert st["peak_bytes_in_use"] == st["peak_in_use"] * st["page_bytes"]
    # equal num_pages, ~4x fewer bytes per page than the float32 default
    # (2 int8 pools + 2 fp32 scale rows vs 2 fp32 pools)
    assert eng_ref.stats()["pages"]["page_bytes"] > 3 * st["page_bytes"]


@pytest.mark.parametrize("conn", SIX_STYLES)
def test_quantized_prefix_hit_identity_styles(conn):
    """Prefix-cache hit vs cold prefill under kv_dtype=int8: shared
    quantized pages (values + scales) adopted at admission must reproduce
    the cold engine's tokens bit-exactly, for every connection style —
    the history-free per-row scales make cached pages position-content
    pure, so a hit is indistinguishable from a re-prefill."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection=conn)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sysp = np.random.default_rng(3).integers(1, cfg.vocab, 16)  # 2 pages
    tail = np.random.default_rng(5).integers(1, cfg.vocab, 5)
    prompt = np.concatenate([sysp, tail])

    hot_out, hot = _run_engine(cfg, params, [sysp], kv_dtype="int8",
                               prefix_cache=True)
    probe = _req(2, prompt)
    hot.submit(probe)
    hot.run()
    assert probe.prefix_hit_tokens == 16, conn

    cold_out, _ = _run_engine(cfg, params, [prompt], kv_dtype="int8",
                              prefix_cache=True)
    assert tuple(probe.generated) == cold_out[0], conn
    hot.pcache.clear()
    assert hot.allocator.in_use == 0


def test_quantized_kernel_dispatch_telemetry():
    """Quantized paged dispatches trace under ``<site>.int8`` — runtime
    telemetry separates the quantized engine's kernel path rows.  The
    registry records at jit-trace time, so this test's engines use dims
    no other test shares (a cached executable would skip the trace)."""
    ops.reset_dispatch_paths()
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.arange(1, 10)]
    dims = dict(page_size=4, max_seq=36)
    _run_engine(cfg, params, prompts, kv_dtype="int8", **dims)
    paths = ops.dispatch_paths()
    assert "paged_packed_attention.int8" in paths, paths
    _run_engine(cfg, params, prompts, **dims)
    paths = ops.dispatch_paths()
    assert "paged_packed_attention" in paths, paths


def test_quantized_spec_decode_identity():
    """Self-speculative decoding over a quantized cache: draft, verify and
    rollback all read/write int8 pages + scale pools; greedy streams must
    stay identical to the non-spec quantized engine."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab, n) for n in (5, 9)]
    out_plain, _ = _run_engine(cfg, params, prompts, kv_dtype="int8")
    out_spec, eng = _run_engine(cfg, params, prompts, kv_dtype="int8",
                                spec_tokens=3, draft_blocks=1)
    assert out_spec == out_plain
    assert eng.stats()["dispatches_per_tick"] == 1.0
