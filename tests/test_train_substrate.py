"""Training-substrate tests: loss decreases, checkpoint roundtrip, schedules,
gradient-compression baselines, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticMarkov, unigram_entropy
from repro.optim import adamw, grad_compress, schedules
from repro.serve.decode import ContinuousBatcher, Request
from repro.train import checkpoint as ckpt
from repro.train import step as tstep
from repro.train import trainer


def tiny_cfg(**kw):
    return get_config("gpt2-117m").reduced().replace(
        vocab=256, max_seq=64, **kw)


def test_training_reduces_loss():
    cfg = tiny_cfg(connection="fal")
    data = SyntheticMarkov(cfg.vocab, 32, 8, seed=5)
    state, hist = trainer.train(cfg, steps=60, batch=8, seq_len=32,
                                data=data, log_every=59, lr=2e-3)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first - 0.3, (first, last)
    assert last < np.log(cfg.vocab)  # beats uniform


def test_microbatched_grads_match_full_batch():
    cfg = tiny_cfg()
    ocfg = adamw.AdamWConfig(lr=1e-3)
    state = tstep.init_state(jax.random.PRNGKey(0), cfg, ocfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                          0, cfg.vocab)}
    s1, m1 = jax.jit(tstep.make_train_step(cfg, ocfg, None, 1))(state, batch)
    s2, m2 = jax.jit(tstep.make_train_step(cfg, ocfg, None, 4))(state, batch)
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])))
    assert diff < 1e-5, diff


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    ocfg = adamw.AdamWConfig()
    state = tstep.init_state(jax.random.PRNGKey(0), cfg, ocfg)
    ckpt.save(str(tmp_path), state, step=7, meta={"arch": cfg.arch_id})
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored = ckpt.restore(str(tmp_path), state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert jnp.array_equal(a, b)


def test_schedules():
    for make in (schedules.warmup_cosine, schedules.one_cycle, schedules.wsd):
        f = make(1e-3, 100)
        vals = np.array([float(f(s)) for s in range(1, 101)])
        assert vals.max() <= 1e-3 + 1e-9
        assert vals.min() >= 0
        assert vals.argmax() < 50  # peak in first half


def test_grad_compress_lossy_but_bounded():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    q = grad_compress.quantize_int8(g)
    err = float(jnp.max(jnp.abs(q["w"] - g["w"])))
    assert 0 < err < float(jnp.max(jnp.abs(g["w"]))) / 64
    lr = grad_compress.lowrank(g, rank=4)
    assert lr["w"].shape == g["w"].shape
    # rank-4 approx of a random matrix loses energy
    assert float(jnp.linalg.norm(lr["w"])) < float(jnp.linalg.norm(g["w"]))
    assert grad_compress.compressed_bytes(g, "int8") < \
        grad_compress.compressed_bytes(g, "none")


def test_continuous_batcher_end_to_end():
    cfg = tiny_cfg(connection="fal")
    from repro.models import model as M
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatcher(cfg, params, batch_slots=2, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5),
                    max_new=4 + i) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert len(r.generated) >= r.max_new
        assert all(0 <= t < cfg.vocab for t in r.generated)


def test_batcher_matches_sequential_decode():
    """Continuous batching must produce the same tokens as a lone request."""
    cfg = tiny_cfg(connection="fal")
    from repro.models import model as M
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(1, 7) % cfg.vocab

    eng1 = ContinuousBatcher(cfg, params, batch_slots=1, max_seq=32)
    eng1.submit(Request(rid=0, prompt=prompt, max_new=5))
    ref = eng1.run()[0].generated

    eng2 = ContinuousBatcher(cfg, params, batch_slots=2, max_seq=32)
    eng2.submit(Request(rid=0, prompt=prompt, max_new=5))
    eng2.submit(Request(rid=1, prompt=prompt[::-1].copy(), max_new=7))
    out = {r.rid: r.generated for r in eng2.run()}
    assert out[0] == ref
