"""Observability layer (repro.obs): metrics registry (log-bucket
histogram percentiles vs the numpy reference, reset semantics, Prometheus
export), Chrome-trace tracer (schema-valid export, disabled-tracer cost
model), engine request-lifecycle events surviving preemption +
re-prefill, runtime kernel-dispatch telemetry, and run metadata."""
import json

import numpy as np
import pytest

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry)
from repro.obs.runmeta import run_metadata
from repro.obs.trace import NULL_TRACER, Tracer, validate_chrome_trace


# --------------------------------------------------------------------------- #
# histograms
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dist,lo", [("uniform", 1.0), ("lognormal", None)])
def test_histogram_percentiles_match_numpy(dist, lo):
    """Log buckets at base 1.05 bound the relative error vs the exact
    sorted-sample percentile by roughly one bucket width (~5%)."""
    rng = np.random.default_rng(0)
    xs = (rng.uniform(1.0, 100.0, 5000) if dist == "uniform"
          else rng.lognormal(mean=2.0, sigma=1.0, size=5000))
    h = Histogram("t")
    for x in xs:
        h.record(float(x))
    for p in (50, 90, 99):
        ref = float(np.percentile(xs, p))
        got = h.percentile(p)
        assert abs(got - ref) / ref < 0.08, (dist, p, got, ref)
    assert h.count == len(xs)
    assert h.min == pytest.approx(xs.min())
    assert h.max == pytest.approx(xs.max())
    assert h.mean == pytest.approx(xs.mean())


def test_histogram_edge_cases():
    h = Histogram("t")
    assert h.percentile(50) == 0.0 and h.mean == 0.0       # empty
    s = h.summary()
    assert s["count"] == 0 and s["min"] == 0.0 and s["max"] == 0.0
    h.record(0.0)                                           # underflow bucket
    h.record(-3.0)
    assert h.percentile(50) <= 0.0
    h2 = Histogram("u")
    h2.record(7.0)                                          # single sample:
    assert h2.percentile(50) == pytest.approx(7.0)          # clamped to
    assert h2.percentile(99) == pytest.approx(7.0)          # exact extrema


def test_histogram_percentile_extremes_match_numpy():
    """p=0 must return the recorded MINIMUM exactly (the old rank-0 walk
    stopped at the first bucket and returned its midpoint — badly wrong
    for skewed data) and p=100 the maximum; both interact correctly with
    the underflow bucket that absorbs every non-positive sample."""
    rng = np.random.default_rng(3)
    xs = rng.lognormal(mean=1.0, sigma=2.0, size=500)
    h = Histogram("t")
    for x in xs:
        h.record(float(x))
    assert h.percentile(0) == float(xs.min())       # exact, not a midpoint
    assert h.percentile(100) == float(xs.max())
    assert h.percentile(-5) == float(xs.min())      # clamped below 0
    assert h.percentile(101) == float(xs.max())     # clamped above 100
    # rank-1 inside the underflow bucket is the recorded min, not 0
    h2 = Histogram("u")
    for v in (-3.0, 0.0, 5.0, 40.0):
        h2.record(v)
    assert h2.percentile(0) == -3.0
    assert h2.percentile(25) == -3.0
    assert h2.percentile(100) == 40.0


def test_counter_gauge_and_get_or_create():
    reg = MetricsRegistry()
    c = reg.counter("ticks", unit="ticks")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert reg.counter("ticks") is c                # get-or-create identity
    g = reg.gauge("occ")
    g.set(0.75)
    assert reg.gauge("occ").value == 0.75
    with pytest.raises(TypeError, match="already registered"):
        reg.histogram("ticks")                      # type mismatch is loud


def test_registry_reset_zeroes_every_series():
    reg = MetricsRegistry()
    reg.counter("c").inc(9)
    reg.gauge("g").set(1.5)
    h = reg.histogram("h")
    for v in (1.0, 2.0, 3.0):
        h.record(v)
    reg.reset()
    # registration survives; every value is zeroed
    assert reg.names() == ["c", "g", "h"]
    assert reg.counter("c").value == 0
    assert reg.gauge("g").value == 0.0
    assert h.count == 0 and h.total == 0.0 and h._buckets == {}
    d = reg.to_dict()
    assert d["c"]["value"] == 0 and d["h"]["count"] == 0


def test_registry_json_and_prometheus_export():
    reg = MetricsRegistry()
    reg.counter("ticks", unit="ticks").inc(3)
    reg.gauge("occ").set(0.5)
    reg.histogram("lat_ms", unit="ms").record(10.0)
    d = reg.to_dict()
    assert d["ticks"] == {"type": "counter", "unit": "ticks", "value": 3}
    assert d["lat_ms"]["type"] == "histogram" and d["lat_ms"]["count"] == 1
    json.dumps(d)                                   # JSON-serializable
    text = reg.prometheus_text()
    assert "# TYPE repro_ticks counter\nrepro_ticks 3" in text
    assert "# TYPE repro_occ gauge\nrepro_occ 0.5" in text
    assert 'repro_lat_ms{quantile="0.5"}' in text
    assert "repro_lat_ms_sum 10.0" in text and "repro_lat_ms_count 1" in text


# --------------------------------------------------------------------------- #
# tracer
# --------------------------------------------------------------------------- #
def test_tracer_exports_valid_chrome_trace(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("tick", tick=1):
        with tr.span("dispatch", cat="kernel", lanes=2):
            pass
    tr.instant("ADMITTED", rid=0, slot=1)
    tr.begin_async("req", 7, prompt_len=5)
    tr.counter("occupancy", 0.5)
    tr.end_async("req", 7, outcome="finished")
    obj = tr.export()
    n = validate_chrome_trace(obj)
    assert n == 1 + 6                               # process_name meta + events
    by_ph = {}
    for ev in obj["traceEvents"]:
        by_ph.setdefault(ev["ph"], []).append(ev)
    assert len(by_ph["X"]) == 2 and all("dur" in e for e in by_ph["X"])
    # inner span closed first -> recorded first; nesting visible via ts/dur
    outer = next(e for e in by_ph["X"] if e["name"] == "tick")
    inner = next(e for e in by_ph["X"] if e["name"] == "dispatch")
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert by_ph["b"][0]["id"] == by_ph["e"][0]["id"] == 7
    assert by_ph["i"][0]["args"] == {"rid": 0, "slot": 1}
    # round-trips through the file writer
    p = tmp_path / "trace.json"
    tr.write(str(p))
    assert validate_chrome_trace(json.load(open(p))) == n


def test_tracer_disabled_records_nothing():
    tr = Tracer(enabled=False)
    ctx = tr.span("tick")
    with ctx:
        tr.instant("X")
        tr.begin_async("req", 1)
        tr.counter("c", 1.0)
    assert tr.events == []
    assert tr.span("other") is ctx                  # shared no-op context
    assert NULL_TRACER.events == []
    assert validate_chrome_trace(NULL_TRACER.export()) == 1   # meta only


def test_tracer_clear_resets_epoch():
    tr = Tracer(enabled=True)
    with tr.span("a"):
        pass
    tr.clear()
    assert tr.events == []
    with tr.span("b"):
        pass
    assert tr.events[0]["ts"] >= 0                  # new epoch, ts stays valid
    validate_chrome_trace(tr.export())


def test_validate_chrome_trace_rejects_malformed():
    ok = {"name": "a", "ph": "i", "pid": 0, "tid": 0, "ts": 1.0, "s": "t"}
    validate_chrome_trace({"traceEvents": [ok]})
    bad = [
        {"traceEvents": [{**ok, "ph": "Z"}]},                 # unknown phase
        {"traceEvents": [{k: v for k, v in ok.items() if k != "ts"}]},
        {"traceEvents": [{**ok, "ph": "X"}]},                 # X without dur
        {"traceEvents": [{**ok, "ph": "b"}]},                 # async sans id
        {"traceEvents": [{**ok, "ts": -1.0}]},
        {"traceEvents": "nope"},
        {"events": []},
    ]
    for obj in bad:
        with pytest.raises(ValueError):
            validate_chrome_trace(obj)


# --------------------------------------------------------------------------- #
# engine lifecycle + dispatch telemetry (slow half: real engine runs)
# --------------------------------------------------------------------------- #
def _engine(tracer=None, num_pages=48, slots=4):
    import jax
    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.serve.scheduler import EngineConfig, PagedEngine
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, PagedEngine(cfg, params, EngineConfig(
        page_size=8, num_pages=num_pages, slots=slots, prefill_chunk=8,
        max_seq=64), tracer=tracer)


def _events(tracer, rid):
    return [e["name"] for e in tracer.events
            if e.get("args", {}).get("rid") == rid
            or (e["ph"] in ("b", "e") and e.get("id") == rid)]


def test_engine_lifecycle_events_survive_preemption():
    """Tight page pool: a preempted request's trace must show the full
    QUEUED -> ADMITTED -> PREFILL -> ... -> PREEMPTED -> ADMITTED ->
    PREFILL -> DECODE -> FINISHED arc, with its async req span closed
    exactly once."""
    import numpy as np_
    from repro.serve.scheduler import ServeRequest
    tracer = Tracer(enabled=True)
    cfg, eng = _engine(tracer=tracer, num_pages=9)
    rng = np_.random.default_rng(1)
    for i in range(10):
        eng.submit(ServeRequest(rid=i, prompt=rng.integers(0, cfg.vocab,
                                                           4 + i % 7),
                                max_new=6 + 3 * (i % 3)))
    done = eng.run()
    assert len(done) == 10
    st = eng.stats()
    assert st["preemptions"] > 0
    victim = next(r.rid for r in done if r.preemptions > 0)
    seq = _events(tracer, victim)
    i_pre = seq.index("PREEMPTED")
    assert seq[:3] == ["req", "QUEUED", "ADMITTED"]   # b-event then instants
    assert "PREFILL" in seq[:i_pre]                   # first residency
    after = seq[i_pre:]
    assert "ADMITTED" in after and "PREFILL" in after # re-admitted+re-prefill
    assert "DECODE" in after and after[-2:] == ["FINISHED", "req"]
    assert seq.count("req") == 2                      # one b + one e
    # every request's async span opens and closes exactly once
    for r in done:
        s = _events(tracer, r.rid)
        assert s.count("req") == 2 and s.count("FINISHED") == 1
    validate_chrome_trace(tracer.export())
    # engine-measured latency summaries populated
    assert st["ttft_ms"]["count"] == 10 and st["ttft_ms"]["p50"] > 0
    assert st["inter_token_ms"]["count"] > 0
    assert st["queue_wait_ticks"]["count"] >= 10      # re-admissions count too


def test_engine_stats_reset_zeroes_registry_and_trace():
    from repro.serve.scheduler import ServeRequest
    tracer = Tracer(enabled=True)
    cfg, eng = _engine(tracer=tracer)
    eng.submit(ServeRequest(rid=0, prompt=np.arange(6) % cfg.vocab,
                            max_new=4))
    eng.run()
    assert eng.metrics.counter("engine_ticks_total").value > 0
    assert tracer.events
    eng.reset_stats()
    assert tracer.events == []
    for name in eng.metrics.names():
        s = eng.metrics.get(name)
        assert getattr(s, "count", getattr(s, "value", 0)) in (0, 0.0), name
    st = eng.stats()
    assert st["ticks"] == 0 and st["ttft_ms"]["count"] == 0


def test_kernel_dispatch_paths_runtime_measured():
    """The engine run above traced the packed paged-attention dispatcher;
    on the CPU backend the registry must report cpu-fallback for it, and
    the trace-count counter must live in the default registry."""
    import jax
    from repro.kernels import ops
    from repro.serve.scheduler import ServeRequest
    tracer_cfg, eng = _engine()
    eng.submit(ServeRequest(rid=0, prompt=np.arange(6) % tracer_cfg.vocab,
                            max_new=3))
    eng.run()
    paths = ops.dispatch_paths()
    assert "paged_packed_attention" in paths
    if jax.default_backend() == "cpu":
        assert paths["paged_packed_attention"] == "cpu-fallback"
    name = f"kernel_dispatch_total.paged_packed_attention." \
           f"{paths['paged_packed_attention']}"
    assert default_registry().counter(name).value >= 1
    # engine stats' dispatch telemetry and BENCH stamping both read this map
    assert set(paths.values()) <= {"fused-tpu", "cpu-fallback"}


def test_run_metadata_shape():
    meta = run_metadata(timestamp=123.0, repo_dir=".",
                        dispatch_paths={"x": "cpu-fallback"})
    for k in ("git_sha", "jax_version", "backend", "device_kind",
              "device_count", "python", "platform"):
        assert k in meta, k
    assert meta["timestamp"] == 123.0
    assert meta["dispatch_paths"] == {"x": "cpu-fallback"}
    assert isinstance(meta["device_count"], int) and meta["device_count"] >= 1
    json.dumps(meta)                                  # stampable into JSON
    # omitted optionals stay absent (BENCH files stay minimal)
    assert "timestamp" not in run_metadata()
