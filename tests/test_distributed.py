"""Distributed behaviour tests (forced host devices via subprocess so the
rest of the suite keeps seeing 1 device).

Covers: TP all-reduce halving on the unified DecoderLM blocks (the paper's
claim, asserted structurally on lowered HLO), explicit-TP logits equivalence
across all six connection modes — replicated AND sequence-parallel
(ExecutionPlan sp=True) — the SP reduce-scatter bytes contract, the
shard_map train step, sharded-MoE == oracle, and a full-config dry-run
lower+compile.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(script, devices=8, timeout=600):
    # JAX_PLATFORMS=cpu: on hosts with libtpu installed but no TPU attached,
    # leaving the platform unset makes the subprocess hang on TPU-metadata
    # probes and die; the forced host-device count only applies to CPU anyway
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_tp_allreduce_halving():
    """Structural Fig 2 on the unified DecoderLM blocks: fal lowers to
    exactly ONE all-reduce per steady-state block (scan body), preln to two,
    with block 0 unscanned (fal pays its one extra assemble there)."""
    out = run_py("""
import jax, jax.numpy as jnp, json
from repro.core import tp
mesh = jax.make_mesh((8,), ('model',))
res = {}
for mode in ['preln', 'fal', 'parallel', 'falplus', 'ablation1', 'ablation2']:
    init, fwd = tp.make_tp_forward(mesh, 4, 64, 256, 8, mode)
    p = init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    txt = fwd.lower(p, x).compile().as_text()
    res[mode] = tp.count_collectives(txt).get('all-reduce', 0)
print(json.dumps(res))
""")
    res = json.loads(out.strip().splitlines()[-1])
    # block0 unscanned + scan body (counted once):
    # preln: 2 + 2;  fal: 2 (block0 assembles a1) + 1;  parallel: 1 + 1;
    # ablation1 normalises its OWN attention -> assembled like preln;
    # ablation2: block0 keeps the direct connection (2), later blocks fuse
    assert res["preln"] == 4
    assert res["fal"] == 3
    assert res["parallel"] == 2
    assert res["falplus"] == 4
    assert res["ablation1"] == 4
    assert res["ablation2"] == 3


def test_sp_reduce_scatter_structure():
    """Sequence-parallel contract on lowered HLO: each replicated
    all-reduce becomes exactly one reduce-scatter at 1/tp the bytes (block 0
    under fal/falplus keeps its ONE true all-reduce — the first-attention
    export), so ar_sp + tp * rs_sp == ar_replicated at equal reduce count."""
    out = run_py("""
import jax, jax.numpy as jnp, json
from repro.core import tp
mesh = jax.make_mesh((8,), ('model',))
res = {}
for mode in ['preln', 'fal', 'parallel', 'falplus']:
    row = {}
    for sp in (False, True):
        init, fwd = tp.make_tp_forward(mesh, 4, 64, 256, 8, mode, sp=sp)
        p = init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
        txt = fwd.lower(p, x).compile().as_text()
        row['sp' if sp else 'repl'] = {
            'n': tp.count_collectives(txt), 'b': tp.collective_bytes(txt)}
    res[mode] = row
print(json.dumps(res))
""")
    res = json.loads(out.strip().splitlines()[-1])
    tp_size = 8
    for mode, row in res.items():
        ar_n = row["repl"]["n"].get("all-reduce", 0)
        ar_b = row["repl"]["b"].get("all-reduce", 0)
        assert not row["repl"]["n"].get("reduce-scatter")
        sp_ar_n = row["sp"]["n"].get("all-reduce", 0)
        sp_rs_n = row["sp"]["n"].get("reduce-scatter", 0)
        sp_ar_b = row["sp"]["b"].get("all-reduce", 0)
        sp_rs_b = row["sp"]["b"].get("reduce-scatter", 0)
        # equal reduce-collective count; bytes cut by exactly tp_size
        assert sp_ar_n + sp_rs_n == ar_n, (mode, row)
        assert sp_ar_b + tp_size * sp_rs_b == ar_b, (mode, row)
        # only fal/falplus block 0 pays the full all-reduce (signal export)
        assert sp_ar_n == (1 if mode in ("fal", "falplus") else 0), \
            (mode, row)
        # every reduce-scatter is paired with an all-gather of an LN region
        assert row["sp"]["n"].get("all-gather", 0) >= sp_rs_n - 1, (mode, row)


def test_tp_forward_matches_replicated():
    """tp_size=1 really is the same code path: the 8-way shard_map stack
    must reproduce the 1-way stack bit-for-bit (up to psum reassociation)."""
    out = run_py("""
import jax, jax.numpy as jnp
from repro.core import tp
mesh1 = jax.make_mesh((1,), ('model',))
mesh8 = jax.make_mesh((8,), ('model',))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
for mode in ['preln', 'fal']:
    init1, fwd1 = tp.make_tp_forward(mesh1, 3, 64, 256, 8, mode)
    init8, fwd8 = tp.make_tp_forward(mesh8, 3, 64, 256, 8, mode)
    p = init1(jax.random.PRNGKey(0))
    import numpy as np
    y1 = np.asarray(fwd1(p, x)); y8 = np.asarray(fwd8(p, x))
    err = float(np.max(np.abs(y1 - y8)))
    assert err < 1e-4, (mode, err)
print('OK')
""")
    assert "OK" in out


def test_model_explicit_tp_all_modes_matches_single_device():
    """Real DecoderLM logits under the explicit partial-sum TP stack ==
    single-device forward, for ALL six connection modes — replicated AND
    sequence-parallel (the six-mode SP equivalence of the plan redesign)."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config, VALID_CONNECTIONS
from repro.core.plan import ExecutionPlan
from repro.models import model as M
mesh = jax.make_mesh((2, 4), ('data', 'model'))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 500)
for sp in (False, True):
    for mode in VALID_CONNECTIONS:
        cfg = get_config('llama3.2-3b').reduced().replace(
            connection=mode, n_kv_heads=4)
        plan = ExecutionPlan.from_mesh(mesh, tp='explicit',
                                       sp=sp).validate(cfg)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        b = {'tokens': toks % cfg.vocab}
        ref, _, _ = M.forward(params, cfg, b)
        with mesh:
            y, _, _ = jax.jit(lambda p, b: M.forward(p, cfg, b, plan))(
                params, b)
        err = float(np.max(np.abs(np.asarray(y) - np.asarray(ref))))
        assert err < 5e-4, (sp, mode, err)
print('OK')
""", timeout=900)
    assert "OK" in out


def test_model_explicit_tp_moe_mla_windows():
    """Explicit TP over the rest of the decoder family: MoE partial-sum
    experts (qwen3-moe), MLA + shared experts (deepseek), sliding-window +
    post-norms (gemma2).  qwen3-moe/gemma2 reduced have n_kv_heads=2 <
    tp_size=4, so this also covers the Megatron KV-replication fallback —
    replicated and sequence-parallel."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan
from repro.models import model as M
mesh = jax.make_mesh((2, 4), ('data', 'model'))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 500)
cases = [('qwen3-moe-30b-a3b', {}),
         ('deepseek-v3-671b', {}),
         ('gemma2-27b', {})]
for sp in (False, True):
    for arch, over in cases:
        cfg = get_config(arch).reduced().replace(connection='fal', **over)
        plan = ExecutionPlan.from_mesh(mesh, tp='explicit',
                                       sp=sp).validate(cfg)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        b = {'tokens': toks % cfg.vocab}
        ref, _, _ = M.forward(params, cfg, b)
        with mesh:
            y, _, _ = jax.jit(lambda p, b: M.forward(p, cfg, b, plan))(
                params, b)
        err = float(np.max(np.abs(np.asarray(y) - np.asarray(ref))))
        assert err < 5e-4, (sp, arch, err)
print('OK')
""", timeout=900)
    assert "OK" in out


def test_explicit_tp_train_step():
    """The shard_map partial-sum stack differentiates: one explicit-TP train
    step on the (data, model) mesh matches the single-device loss and moves
    the params — with and without sequence parallelism."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan
from repro.models import model as M
from repro.optim import adamw
from repro.train import step as tstep
cfg = get_config('llama3.2-3b').reduced().replace(
    connection='fal', n_kv_heads=4)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
ocfg = adamw.AdamWConfig(lr=1e-3)
state = tstep.init_state(jax.random.PRNGKey(0), cfg, ocfg)
batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                      cfg.vocab)}
l_ref, _ = M.loss_fn(state['params'], cfg, batch)
for sp in (False, True):
    plan = ExecutionPlan.from_mesh(mesh, tp='explicit', sp=sp)
    with mesh:
        step = jax.jit(tstep.make_train_step(cfg, ocfg, plan))
        new_state, metrics = step(state, batch)
    assert abs(float(metrics['loss']) - float(l_ref)) < 1e-4, sp
    assert bool(jnp.isfinite(metrics['grad_norm']))
    moved = any(float(jnp.max(jnp.abs(a - b))) > 0
                for a, b in zip(jax.tree.leaves(new_state['params']),
                                jax.tree.leaves(state['params'])))
    assert moved
print('OK')
""", timeout=900)
    assert "OK" in out


def test_sharded_moe_matches_oracle_and_grads():
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan
from repro.models import moe as MO
cfg = get_config('qwen3-moe-30b-a3b').reduced().replace(
    n_experts=8, top_k=2, capacity_factor=8.0)
p = MO.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, cfg.d_model)) * 0.5
mesh = jax.make_mesh((2, 4), ('data', 'model'))
plan = ExecutionPlan.from_mesh(mesh)
y_ref, _ = MO.moe_apply(p, cfg, x)
f = jax.jit(lambda p, x: MO.moe_apply_sharded(p, cfg, x, plan))
y_sh, _ = f(p, x)
assert float(jnp.max(jnp.abs(y_sh - y_ref))) < 1e-5
# grads flow through the all_to_all dispatch
g = jax.grad(lambda p: jnp.sum(MO.moe_apply_sharded(
    p, cfg, x, plan)[0] ** 2))(p)
gr = jax.grad(lambda p: jnp.sum(MO.moe_apply(p, cfg, x)[0] ** 2))(p)
for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gr)):
    assert bool(jnp.all(jnp.isfinite(a)))
    assert float(jnp.max(jnp.abs(a - b))) < 1e-3
print('OK')
""")
    assert "OK" in out


def test_model_tp_matches_single_device():
    """Full reduced model: sharded pjit forward == single-device forward."""
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan
from repro.launch import mesh as MX
from repro.models import model as M
cfg = get_config('llama3.2-3b').reduced().replace(connection='fal')
params = M.init_params(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
ref, _, _ = M.forward(params, cfg, {'tokens': toks})
mesh = jax.make_mesh((2, 4), ('data', 'model'))
plan = ExecutionPlan.from_mesh(mesh)          # implicit GSPMD
specs = MX.param_specs(params, cfg)
sh = MX.shardings_for(mesh, specs)
params_sh = jax.device_put(params, sh)
with mesh:
    y, _, _ = jax.jit(lambda p, b: M.forward(p, cfg, b, plan))(
        params_sh, {'tokens': toks})
err = float(jnp.max(jnp.abs(y - ref)))
assert err < 5e-4, err
print('OK', err)
""")
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_full_config_compiles():
    """One representative full-scale dry-run (512 host devices)."""
    out = run_py("""
from repro.launch import dryrun
info, compiled = dryrun.run_one('llama3.2-3b', 'train_4k', 'single',
                                out_dir=None)
assert 'error' not in info, info
assert compiled is not None
print('OK', info['cost']['flops'])
""", devices=512, timeout=900)
    assert "OK" in out


def test_sequence_parallel_attention_matches_auto():
    """§Perf P1: CP attention == baseline numerics (incl. gemma windows)."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan
from repro.models import model as M
mesh = jax.make_mesh((2, 4), ('data', 'model'))
plan = ExecutionPlan.from_mesh(mesh)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 500)
for arch in ['llama3.2-3b', 'gemma2-27b', 'deepseek-v3-671b']:
    cfg0 = get_config(arch).reduced()
    cfg1 = cfg0.replace(attn_shard='sequence')
    params = M.init_params(jax.random.PRNGKey(0), cfg0)
    b = {'tokens': toks % cfg0.vocab}
    ref, _, _ = M.forward(params, cfg0, b)
    with mesh:
        y, _, _ = jax.jit(lambda p, b: M.forward(p, cfg1, b, plan))(
            params, b)
    err = float(np.max(np.abs(np.asarray(y) - np.asarray(ref))))
    assert err < 5e-4, (arch, err)
print('OK')
""")
    assert "OK" in out


def test_shard_slot_moe_matches_oracle():
    """§Perf D3/D4: group-limited shard-slot dispatch == oracle (+grads)."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan
from repro.models import moe as MO
cfg = get_config('qwen3-moe-30b-a3b').reduced().replace(
    n_experts=8, top_k=2, capacity_factor=8.0,
    route_groups=4, route_group_limit=2)
p = MO.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, cfg.d_model)) * 0.5
mesh = jax.make_mesh((2, 4), ('data', 'model'))
plan = ExecutionPlan.from_mesh(mesh)
y_ref, _ = MO.moe_apply(p, cfg, x)
y_sh, _ = jax.jit(lambda p, x: MO.moe_apply_shard_slot(
    p, cfg, x, plan))(p, x)
assert float(jnp.max(jnp.abs(np.asarray(y_sh) - np.asarray(y_ref)))) < 3e-5
g = jax.grad(lambda p: jnp.sum(MO.moe_apply_shard_slot(
    p, cfg, x, plan)[0] ** 2))(p)
gr = jax.grad(lambda p: jnp.sum(MO.moe_apply(p, cfg, x)[0] ** 2))(p)
for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gr)):
    assert float(jnp.max(jnp.abs(np.asarray(a) - np.asarray(b)))) < 1e-3
print('OK')
""")
    assert "OK" in out


def test_group_limited_routing_respects_limit():
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs.base import get_config
from repro.models import moe as MO
cfg = get_config('deepseek-v3-671b').reduced().replace(
    n_experts=16, top_k=4, route_groups=4, route_group_limit=2)
p = MO.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
w, e, aux = MO._route(p, cfg, x)
for row in (e // (16 // 4)):   # group id of each chosen expert
    assert len(set(int(v) for v in row)) <= 2  # <= route_group_limit groups
print('OK')
""", devices=1)
    assert "OK" in out
