"""Radix prefix cache + refcounted COW paged KV: allocator refcount
semantics (double-free raises, share/free round-trips), radix-tree
invariants (hypothesis: insert/match round-trips, refcount conservation,
evictions never drop a referenced page), the device page-copy oracle, and
engine-level token/a1_sig bit-identity — prefix-hit vs cold prefill across
all six connection styles, dual-branch, and through preemption — with the
allocator ending every test fully free."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.kernels import ops, ref
from repro.models import model as M
from repro.serve import sampling as SP
from repro.serve.paged_cache import BlockTable, PageAllocator
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import EngineConfig, PagedEngine, ServeRequest

SIX_STYLES = ("preln", "parallel", "fal", "falplus", "ablation1",
              "ablation2")


# --------------------------------------------------------------------------- #
# allocator refcounts
# --------------------------------------------------------------------------- #
def test_allocator_double_free_raises():
    a = PageAllocator(num_pages=8, page_size=4)
    got = a.alloc(2)
    a.free(got)
    with pytest.raises(RuntimeError, match="double free"):
        a.free(got[:1])
    assert a.in_use == 0


def test_allocator_share_free_roundtrip():
    a = PageAllocator(num_pages=8, page_size=4)
    got = a.alloc(2)
    a.share(got)                      # second owner
    assert a.shared_pages == 2 and a.refcount(got[0]) == 2
    a.free(got)                       # first owner lets go
    assert a.in_use == 2              # still held by the second owner
    assert a.shared_pages == 0
    a.free(got)                       # last owner -> recycled
    assert a.in_use == 0
    with pytest.raises(RuntimeError):
        a.share(got)                  # free pages can't gain owners


def test_block_table_adopt_cow_replace():
    a = PageAllocator(num_pages=16, page_size=4)
    owner = a.alloc(2)                # "the tree's" pages
    t = BlockTable(a, max_blocks=8)
    a.share(owner)
    t.adopt(owner)
    assert t.first_shared_block(0, 8) == 0
    assert t.first_shared_block(4, 8) == 1
    new = a.alloc(1)
    old = t.replace(0, new[0])
    assert old == owner[0] and a.refcount(old) == 1   # tree's ref survives
    assert t.first_shared_block(0, 4) is None         # block 0 private now
    t.release()
    a.free(owner)                     # tree lets go
    assert a.in_use == 0


# --------------------------------------------------------------------------- #
# radix tree (deterministic)
# --------------------------------------------------------------------------- #
def _mk(page=4, pages=64):
    a = PageAllocator(num_pages=pages, page_size=page)
    return a, PrefixCache(a)


def _cached_insert(pc, a, toks):
    """Simulate a finishing request: alloc, insert (tree takes its ref),
    release the request's own pages."""
    toks = np.asarray(toks, np.int64)
    pages = a.alloc(len(toks) // a.page_size)
    assert pages is not None
    pc.insert(toks, pages)
    a.free(pages)
    return toks


def test_radix_match_page_aligned_and_divergence():
    a, pc = _mk(page=4)
    _cached_insert(pc, a, list(range(12)))            # 3 pages
    n, pages, _ = pc.match(np.asarray(list(range(12)) + [99]))
    assert n == 12 and len(pages) == 3
    # divergence inside page 2 -> only whole matching pages count
    n, pages, _ = pc.match(np.asarray(list(range(9)) + [99, 99, 99]))
    assert n == 8 and len(pages) == 2
    # divergence inside page 0 -> miss
    n, pages, _ = pc.match(np.asarray([99] * 12))
    assert n == 0 and pages == []
    # sibling insert sharing 2 pages then diverging: splits at the boundary
    _cached_insert(pc, a, list(range(8)) + [50, 51, 52, 53])
    n, pages, _ = pc.match(np.asarray(list(range(8)) + [50, 51, 52, 53]))
    assert n == 12
    n2, _, _ = pc.match(np.asarray(list(range(12))))
    assert n2 == 12
    # 2 shared pages + range(12)'s third + the sibling's divergent page
    assert a.in_use == pc.n_pages == 4
    pc.clear()
    assert a.in_use == 0


def test_radix_a1_sig_roundtrip():
    a, pc = _mk(page=4)
    toks = np.arange(8)
    sig = np.arange(16, dtype=np.float32)
    pages = a.alloc(2)
    pc.insert(toks, pages, a1={7: sig})
    a.free(pages)
    n, _, a1 = pc.match(np.concatenate([toks, [9, 9, 9, 9]]))
    assert n == 8 and np.array_equal(a1[7], sig)
    # a partial match short of the position must NOT surface the sig
    n, _, a1 = pc.match(np.asarray([0, 1, 2, 3, 9, 9, 9, 9]))
    assert n == 4 and 7 not in a1
    # edge split keeps the sig on the right side
    pages = a.alloc(2)
    pc.insert(np.asarray([0, 1, 2, 3, 20, 21, 22, 23]), pages)
    a.free(pages)
    n, _, a1 = pc.match(np.concatenate([toks, [9] * 4]))
    assert n == 8 and np.array_equal(a1[7], sig)
    pc.clear()
    assert a.in_use == 0


def test_radix_eviction_lru_and_referenced_pages_survive():
    a, pc = _mk(page=4, pages=64)
    t1 = _cached_insert(pc, a, list(range(0, 8)))
    t2 = _cached_insert(pc, a, list(range(100, 108)))
    pc.match(t2)                                 # t2 is now most-recent
    n, held, _ = pc.match(t1)
    a.share(held)                                # simulate a live admission
    # t1's pages are referenced -> only t2 (LRU among free) is evictable
    freed = pc.evict(100)
    assert freed == 2 and pc.n_pages == 2
    n, _, _ = pc.match(t1)
    assert n == 8                                # referenced node survived
    n, _, _ = pc.match(t2)
    assert n == 0                                # unreferenced LRU evicted
    a.free(held)                                 # admission ends
    assert pc.evict(100) == 2                    # now evictable
    assert pc.n_pages == 0 and a.in_use == 0


def test_radix_eviction_cascades_through_split_chain():
    a, pc = _mk(page=4)
    _cached_insert(pc, a, list(range(16)))       # 4-page chain
    _cached_insert(pc, a, list(range(8)) + [50, 51, 52, 53])  # split at 8
    assert pc.n_pages == 5
    assert pc.evict(100) == 5                    # leaves, then exposed parents
    assert pc.n_pages == 0 and a.in_use == 0


def test_radix_max_pages_budget():
    a = PageAllocator(num_pages=64, page_size=4)
    pc = PrefixCache(a, max_pages=3)
    _cached_insert(pc, a, list(range(8)))
    _cached_insert(pc, a, list(range(100, 112)))  # 3 pages; budget forces LRU
    assert pc.n_pages <= 3
    pc.clear()
    assert a.in_use == 0


def test_radix_pinned_nodes_resist_eviction():
    a, pc = _mk(page=4)
    toks = np.arange(8)
    pages = a.alloc(2)
    pc.insert(toks, pages, pinned=True)
    a.free(pages)
    _cached_insert(pc, a, list(range(100, 108)))
    assert pc.evict(100) == 2                    # only the unpinned node
    n, _, _ = pc.match(np.concatenate([toks, [9] * 4]))
    assert n == 8
    pc.clear()
    assert a.in_use == 0


# --------------------------------------------------------------------------- #
# radix tree invariants (hypothesis when available, with a seeded
# random-walk fallback so the properties run in hypothesis-free containers)
# --------------------------------------------------------------------------- #
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=20, deadline=None)


def _aligned_common(x, y, page):
    m = 0
    lim = min(len(x), len(y))
    while m < lim and x[m] == y[m]:
        m += 1
    return (m // page) * page


def _check_insert_match_roundtrip(page, seqs, queries):
    """match == the longest page-aligned common prefix over everything
    inserted (the tree IS the union of its inserted prefixes), and every
    page the tree holds is owned exactly once by the tree."""
    a = PageAllocator(num_pages=256, page_size=page)
    pc = PrefixCache(a)
    model = []
    for s in seqs:
        _cached_insert(pc, a, s)
        model.append(s)
        assert a.in_use == pc.n_pages      # tree is the only owner
    for q in model + queries:
        q = np.asarray(q, np.int64)
        n, pages, _ = pc.match(q)
        want = max((_aligned_common(np.asarray(s), q, page)
                    for s in model), default=0)
        assert n == want
        assert len(pages) == n // page
        # an admission holds + releases the matched pages: no leak
        if len(pages):
            a.share(pages)
            a.free(pages)
    assert a.in_use == pc.n_pages
    pc.clear()
    assert a.in_use == 0                   # zero leaked refcounts


def _check_eviction_conservation(page, seqs, evict_every):
    """Random insert/evict interleaving: pages freed by eviction really
    return to the pool, referenced pages never do, and clear() always
    drains the tree to a fully-free allocator."""
    a = PageAllocator(num_pages=256, page_size=page)
    pc = PrefixCache(a)
    held = []
    for k, s in enumerate(seqs):
        _cached_insert(pc, a, s)
        if not held:                       # keep one admission live
            n, pages, _ = pc.match(np.asarray(s, np.int64))
            if len(pages):
                a.share(pages)
                held = pages
        if evict_every and k % evict_every == 0:
            pc.evict(1)
        # every in-use page is either tree-owned or our exclusive hold
        assert a.in_use == pc.n_pages + sum(
            1 for pg in held if a.refcount(pg) == 1)
    if held:                               # held pages must all be alive
        assert all(a.refcount(pg) >= 1 for pg in held)
        a.free(held)
    pc.evict(10 ** 6)
    assert pc.n_pages == 0
    pc.clear()
    assert a.in_use == 0


def _random_workload(rng):
    page = int(rng.choice([2, 4]))
    seqs = []
    for _ in range(rng.integers(1, 7)):
        raw = rng.integers(0, 4, rng.integers(page, 4 * page + 1))
        al = (len(raw) // page) * page
        if al:
            seqs.append(list(raw[:al]))
    seqs = seqs or [[0] * page]
    queries = [list(rng.integers(0, 4, rng.integers(0, 5 * page + 1)))
               for _ in range(4)]
    return page, seqs, queries


def test_radix_insert_match_roundtrip_model_seeded():
    for seed in range(40):
        rng = np.random.default_rng(seed)
        page, seqs, queries = _random_workload(rng)
        _check_insert_match_roundtrip(page, seqs, queries)


def test_radix_eviction_conservation_seeded():
    for seed in range(40):
        rng = np.random.default_rng(seed)
        page, seqs, _ = _random_workload(rng)
        _check_eviction_conservation(page, seqs, int(rng.integers(0, 4)))


if HAVE_HYPOTHESIS:
    @st.composite
    def _workload(draw):
        page = draw(st.sampled_from([2, 4]))
        seqs = draw(st.lists(
            st.lists(st.integers(0, 3), min_size=page, max_size=4 * page),
            min_size=1, max_size=6))
        seqs = [s[:(len(s) // page) * page] for s in seqs]
        seqs = [s for s in seqs if s]
        queries = draw(st.lists(
            st.lists(st.integers(0, 3), min_size=0, max_size=5 * page),
            min_size=1, max_size=4))
        return page, seqs, queries

    @given(_workload())
    @settings(**SETTINGS)
    def test_radix_insert_match_roundtrip_model(w):
        page, seqs, queries = w
        if not seqs:
            return
        _check_insert_match_roundtrip(page, seqs, queries)

    @given(_workload(), st.integers(0, 3))
    @settings(**SETTINGS)
    def test_radix_eviction_conservation(w, evict_every):
        page, seqs, _ = w
        if not seqs:
            return
        _check_eviction_conservation(page, seqs, evict_every)


# --------------------------------------------------------------------------- #
# device page copy (COW memcpy)
# --------------------------------------------------------------------------- #
def test_copy_pages_oracle_and_kernel_agree():
    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.normal(size=(6, 4, 2, 3)).astype(np.float32))
    src = jnp.asarray([1, 3, 1], jnp.int32)
    dst = jnp.asarray([4, 2, 5], jnp.int32)
    want = ref.copy_pages_ref(pool, src, dst)
    assert np.array_equal(np.asarray(want[4]), np.asarray(pool[1]))
    assert np.array_equal(np.asarray(want[0]), np.asarray(pool[0]))
    got = ops.copy_pages(pool, src, dst)                  # cpu fallback
    assert np.array_equal(np.asarray(got), np.asarray(want))
    got_pl = ops.copy_pages(pool, src, dst, interpret=True)
    assert np.array_equal(np.asarray(got_pl), np.asarray(want))
    assert "copy_pages" in ops.dispatch_paths()


def test_copy_paged_pages_all_layers():
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    cache = M.init_paged_cache(cfg, 8, 4, 2, "float32")
    rng = np.random.default_rng(1)
    cache = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=x.shape).astype(x.dtype)),
        cache)
    before = jax.tree.map(lambda x: np.asarray(x).copy(), cache)
    new = jax.jit(M.copy_paged_pages, donate_argnums=(0,))(
        cache, jnp.asarray([2], jnp.int32), jnp.asarray([5], jnp.int32))
    for k, pool in new["block0"].items():
        assert np.array_equal(np.asarray(pool[5]), before["block0"][k][2])
        assert np.array_equal(np.asarray(pool[3]), before["block0"][k][3])
    for k, pool in new["blocks"].items():
        assert np.array_equal(np.asarray(pool[:, 5]),
                              before["blocks"][k][:, 2])
        assert np.array_equal(np.asarray(pool[:, 3]),
                              before["blocks"][k][:, 3])
    assert np.array_equal(np.asarray(new["a1_sig"]), before["a1_sig"])


# --------------------------------------------------------------------------- #
# engine-level identity: prefix hit vs cold prefill
# --------------------------------------------------------------------------- #
def _ecfg(**kw):
    base = dict(page_size=8, num_pages=48, slots=2, prefill_chunk=8,
                max_seq=64, cache_dtype="float32", prefix_cache=True)
    base.update(kw)
    return EngineConfig(**base)


def _req(rid, prompt, max_new=4):
    return ServeRequest(rid=rid, prompt=np.asarray(prompt, np.int64),
                        max_new=max_new,
                        sampling=SP.SamplingParams(seed=rid))


def _sys_prompt(cfg, n=16, seed=3):
    return np.random.default_rng(seed).integers(1, cfg.vocab, n)


def _assert_drained(eng):
    """Acceptance: the allocator ends every test fully free — the tree's
    refs are the only ones left, and clear() drops them all."""
    eng.pcache.clear()
    assert eng.allocator.in_use == 0


@pytest.mark.parametrize("conn", SIX_STYLES)
def test_prefix_hit_identity_styles(conn):
    """Hot (radix hit at admission, shared pages + COW) and cold (same
    engine config, empty tree) runs must emit bit-identical tokens and
    capture bit-identical a1_sig prefix artifacts, for every connection
    style.  The hot engine must also skip re-prefill of cached pages:
    its probe prefill dispatch tokens == the divergence suffix only."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection=conn)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sysp = _sys_prompt(cfg)                        # 16 tokens = 2 full pages
    tail = np.random.default_rng(5).integers(1, cfg.vocab, 5)
    prompt = np.concatenate([sysp, tail])

    hot = PagedEngine(cfg, params, _ecfg())
    donor = _req(1, sysp)
    hot.submit(donor)
    hot.run()
    hot.reset_stats()
    probe = _req(2, prompt)
    hot.submit(probe)
    hot.run()
    assert probe.prefix_hit_tokens == 16
    st = hot.stats()
    assert st["prefix"]["hits"] == 1
    # hit admissions skip re-prefill of cached pages: the probe's prefill
    # dispatch tokens are the divergence suffix only (ctx - n_hit = 5,
    # vs 21 for a cold prefill)
    assert st["prefill_tokens"] == len(prompt) - 16

    cold = PagedEngine(cfg, params, _ecfg())       # empty tree = cold path
    probe_c = _req(2, prompt)
    cold.submit(probe_c)
    cold.run()
    assert probe_c.prefix_hit_tokens == 0
    assert probe_c.generated == probe.generated, conn
    assert np.array_equal(probe_c.prefix_sig, probe.prefix_sig), conn
    _assert_drained(hot)
    _assert_drained(cold)


def test_prefix_full_prompt_hit_enters_decode_with_seeded_sig():
    """A full-prompt hit must enter decode on its FIRST tick (TTFT of one
    tick, zero prefill tokens) with a1_sig seeded from the cached entry,
    and still emit exactly the cold engine's tokens."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sysp = _sys_prompt(cfg)                        # page-aligned prompt

    hot = PagedEngine(cfg, params, _ecfg())
    donor = _req(1, sysp)
    hot.submit(donor)
    hot.run()
    hot.reset_stats()
    probe = _req(2, sysp, max_new=5)
    hot.submit(probe)
    hot.run()
    st = hot.stats()
    assert probe.prefix_hit_tokens == len(sysp)
    assert st["prefill_tokens"] == 0               # no re-prefill at all
    assert st["prefix"]["a1_sig_seeded"] == 1
    assert st["prefix"]["cow_copies"] >= 1         # last page privatised
    assert st["ttft_ticks"]["p50"] == 1            # decode on first tick

    cold = PagedEngine(cfg, params, _ecfg())
    probe_c = _req(2, sysp, max_new=5)
    cold.submit(probe_c)
    cold.run()
    assert probe_c.generated == probe.generated
    assert np.array_equal(probe_c.prefix_sig, probe.prefix_sig)
    _assert_drained(hot)
    _assert_drained(cold)


def test_prefix_cow_leaves_other_sharers_bit_identical():
    """Concurrent requests sharing a cached prefix: each one's writes land
    on COW-privatised pages, so every sharer's tokens stay bit-identical
    to its own lone cold run."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sysp = _sys_prompt(cfg)
    rng = np.random.default_rng(11)
    tails = [rng.integers(1, cfg.vocab, 3 + k) for k in range(3)]

    hot = PagedEngine(cfg, params, _ecfg(slots=3))
    donor = _req(0, sysp)
    hot.submit(donor)
    hot.run()
    probes = [_req(10 + k, np.concatenate([sysp, t]), max_new=6)
              for k, t in enumerate(tails)]
    for p in probes:                               # all live at once
        hot.submit(p)
    hot.run()
    assert all(p.prefix_hit_tokens == len(sysp) for p in probes)
    assert hot.stats()["prefix"]["cow_copies"] == 0    # divergence falls on
    # fresh pages here (tails start a new block), so sharing alone carries it
    for p in probes:
        lone = PagedEngine(cfg, params, _ecfg(slots=1, prefix_cache=False))
        ref_req = ServeRequest(rid=p.rid, prompt=p.prompt.copy(),
                               max_new=6,
                               sampling=SP.SamplingParams(seed=p.rid))
        lone.submit(ref_req)
        lone.run()
        assert ref_req.generated == p.generated, p.rid
    _assert_drained(hot)


def test_prefix_hit_identity_dual_branch_and_preemption():
    """The hot path composes with dual-branch dispatch and survives
    preemption: a page-starved prefix-cache engine must still emit exactly
    the tokens of an unconstrained no-cache engine."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sysp = _sys_prompt(cfg)
    rng = np.random.default_rng(13)
    # max_new 10..12: every request's context outgrows 3 pages mid-decode,
    # so two concurrent lanes want 8 of the tight pool's 6 pages
    reqs = lambda: [ServeRequest(                  # noqa: E731
        rid=k, prompt=np.concatenate([sysp, rng.integers(1, cfg.vocab, 2)]),
        max_new=10 + (k % 3), sampling=SP.SamplingParams(seed=k))
        for k in range(6)]

    rng = np.random.default_rng(13)
    ample = PagedEngine(cfg, params, EngineConfig(
        page_size=8, num_pages=64, slots=2, prefill_chunk=8, max_seq=64,
        cache_dtype="float32", dual_branch=True))
    for r in reqs():
        ample.submit(r)
    want = {r.rid: r.generated for r in ample.run()}

    rng = np.random.default_rng(13)
    # capacity 6: the first (cold) pair of lanes alone needs 3 + 4 pages,
    # so relief must escalate past prefix eviction to actual preemption;
    # later pairs fit only because the tree shares the prefix pages
    tight = PagedEngine(cfg, params, _ecfg(
        slots=2, num_pages=7, dual_branch=True))
    for r in reqs():
        tight.submit(r)
    done = tight.run()
    assert len(done) == 6 and not any(r.truncated for r in done)
    got = {r.rid: r.generated for r in done}
    assert tight.stats()["preemptions"] > 0        # pressure really bit
    assert tight.stats()["prefix"]["hits"] > 0     # and the cache really hit
    assert got == want
    _assert_drained(tight)


def test_prefix_preempted_request_reprefills_from_cached_prefix():
    """Preemption must not free tree-shared pages, and the re-admission
    must longest-prefix match again (re-prefill restarts at the cached
    prefix, not token 0)."""
    cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sysp = _sys_prompt(cfg)
    hot = PagedEngine(cfg, params, _ecfg())
    donor = _req(1, sysp)
    hot.submit(donor)
    hot.run()
    cached = hot.pcache.n_pages
    assert cached == 2
    probe = _req(2, np.concatenate(
        [sysp, np.random.default_rng(4).integers(1, cfg.vocab, 3)]))
    hot.submit(probe)
    hot._admit()
    i = hot.slots.index(probe)
    hot._preempt(i)                                # forced preemption
    assert hot.pcache.n_pages == cached            # tree pages survived
    hot.run()
    assert probe.prefix_hit_tokens == len(sysp)    # re-admission hit again
    assert len(probe.generated) == probe.max_new
    _assert_drained(hot)
