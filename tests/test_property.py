"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticMarkov
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MO

SETTINGS = dict(max_examples=20, deadline=None)


@given(st.integers(1, 8), st.integers(8, 64),
       st.floats(0.25, 4.0))
@settings(**SETTINGS)
def test_rmsnorm_scale_invariance(b, d, c):
    """rmsnorm(c*x) ~= rmsnorm(x) for c > 0 (exact up to the eps term)."""
    x = jax.random.normal(jax.random.PRNGKey(b * 100 + d), (b, d)) + 0.1
    p = L.norm_init(d, "rmsnorm")
    y1 = L.norm_apply(p, x, "rmsnorm")
    y2 = L.norm_apply(p, x * c, "rmsnorm")
    assert jnp.max(jnp.abs(y1 - y2)) < 2e-3


@given(st.integers(2, 64), st.integers(1, 4))
@settings(**SETTINGS)
def test_rope_preserves_norm(seq, heads):
    x = jax.random.normal(jax.random.PRNGKey(seq), (1, seq, heads, 32))
    pos = jnp.arange(seq)[None]
    y = L.apply_rope(x, pos)
    nx = jnp.linalg.norm(x, axis=-1)
    ny = jnp.linalg.norm(y, axis=-1)
    assert jnp.max(jnp.abs(nx - ny)) < 1e-4


@given(st.floats(1.0, 100.0))
@settings(**SETTINGS)
def test_softcap_bounds(cap):
    x = jnp.linspace(-1e4, 1e4, 101)
    y = L.softcap(x, cap)
    assert bool(jnp.all(jnp.abs(y) <= cap + 1e-5))
    # monotone
    assert bool(jnp.all(jnp.diff(y) >= 0))


@given(st.integers(0, 30))
@settings(**SETTINGS)
def test_causal_masking_no_future_leak(t):
    """Perturbing tokens strictly after position t must not change the
    blockwise-attention output at t."""
    S = 32
    ks = jax.random.split(jax.random.PRNGKey(t), 4)
    q = jax.random.normal(ks[0], (1, S, 2, 16))
    k = jax.random.normal(ks[1], (1, S, 2, 16))
    v = jax.random.normal(ks[2], (1, S, 2, 16))
    o1 = A.blockwise_attention(q, k, v, causal=True, block_q=8)
    noise = jax.random.normal(ks[3], (1, S - t - 1, 2, 16)) * 10
    k2 = k.at[:, t + 1:].add(noise)
    v2 = v.at[:, t + 1:].add(noise)
    o2 = A.blockwise_attention(q, k2, v2, causal=True, block_q=8)
    assert jnp.max(jnp.abs(o1[:, t] - o2[:, t])) < 1e-4


@given(st.integers(1, 16))
@settings(**SETTINGS)
def test_sliding_window_locality(w):
    """With window w, output at t must ignore keys at positions <= t - w."""
    S = 32
    t = S - 1
    ks = jax.random.split(jax.random.PRNGKey(w), 4)
    q = jax.random.normal(ks[0], (1, S, 2, 16))
    k = jax.random.normal(ks[1], (1, S, 2, 16))
    v = jax.random.normal(ks[2], (1, S, 2, 16))
    o1 = A.blockwise_attention(q, k, v, causal=True, window=w, block_q=8)
    cut = t - w + 1
    if cut <= 0:
        return
    noise = jax.random.normal(ks[3], (1, cut, 2, 16)) * 10
    o2 = A.blockwise_attention(q, k.at[:, :cut].add(noise),
                               v.at[:, :cut].add(noise),
                               causal=True, window=w, block_q=8)
    assert jnp.max(jnp.abs(o1[:, t] - o2[:, t])) < 1e-4


@given(st.integers(4, 64), st.integers(2, 8), st.integers(1, 4))
@settings(**SETTINGS)
def test_moe_routing_weights_normalised(T, E, k):
    k = min(k, E)
    cfg = get_config("qwen3-moe-30b-a3b").reduced().replace(
        n_experts=E, top_k=k, d_model=16, moe_d_ff=8, n_shared_experts=0)
    p = MO.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(T), (T, 16))
    w, e, aux = MO._route(p, cfg, x)
    assert jnp.max(jnp.abs(jnp.sum(w, -1) - 1)) < 1e-5
    assert bool(jnp.all((e >= 0) & (e < E)))
    assert float(aux) >= 0.99  # E * sum f_e P_e >= 1 (Cauchy-Schwarz-ish)


@given(st.integers(2, 20), st.integers(2, 6))
@settings(**SETTINGS)
def test_moe_capacity_dispatch_positions(T, E):
    """Dispatch positions must be unique per expert and < capacity."""
    k = 2
    C = MO._capacity(T, k, E, 1.25)
    e = jax.random.randint(jax.random.PRNGKey(T * E), (T, k), 0, E)
    ef, pos, valid = MO._dispatch_indices(e, k, E, C)
    pairs = set()
    for i in range(T * k):
        if bool(valid[i]):
            key = (int(ef[i]), int(pos[i]))
            assert key not in pairs
            assert int(pos[i]) < C
            pairs.add(key)


_DUAL_ORACLE = {}


def _dual_oracle_cfg_params():
    """Init the fal model once across hypothesis examples (init dominates
    example runtime otherwise)."""
    if not _DUAL_ORACLE:
        cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
        from repro.models import model as M
        _DUAL_ORACLE["cfg"] = cfg
        _DUAL_ORACLE["params"] = M.init_params(jax.random.PRNGKey(0), cfg)
    return _DUAL_ORACLE["cfg"], _DUAL_ORACLE["params"]


@given(st.lists(st.integers(0, 511), min_size=1, max_size=10),
       st.sampled_from([4, 8]))
@settings(max_examples=8, deadline=None)
def test_paged_dual_branch_matches_dense_oracle(prompt, page_size):
    """Random prompt lengths / page sizes: greedy paged DUAL-BRANCH decode
    must match the dense full-forward oracle token-for-token (the serving
    invariant, with the MHA||MLP dispatch in the loop)."""
    from repro.core.plan import ExecutionPlan, Phase
    from repro.models import model as M
    from repro.serve.paged_cache import pages_needed
    cfg, params = _dual_oracle_cfg_params()
    max_new = 3

    # dense oracle: greedy teacher-forced full forward
    toks = list(prompt)
    for _ in range(max_new):
        lg, _, _ = M.forward(params, cfg,
                             {"tokens": jnp.asarray([toks])}, "train")
        toks.append(int(jnp.argmax(lg[0, -1])))
    oracle = toks[len(prompt):]

    # paged dual-branch decode, one token per tick
    plan = ExecutionPlan.single_device(Phase.PAGED, dual_branch=True)
    T = pages_needed(len(prompt) + max_new, page_size)
    cache = M.init_paged_cache(cfg, T + 2, page_size, 1, "float32")
    bt = jnp.arange(1, 1 + T, dtype=jnp.int32)[None]
    step = jax.jit(lambda b, c: M.paged_decode_step(params, cfg, b, c, plan))
    got, cur = [], list(prompt)
    for t in range(len(prompt) + max_new - 1):
        lg, cache = step({"tokens": jnp.asarray([[cur[t]]], jnp.int32),
                          "pos": jnp.asarray([t], jnp.int32),
                          "n_valid": jnp.ones((1,), jnp.int32),
                          "block_tables": bt}, cache)
        if t >= len(prompt) - 1:
            nxt = int(jnp.argmax(lg[0, -1]))
            got.append(nxt)
            cur.append(nxt)
    assert got == oracle, (prompt, page_size, got, oracle)


@st.composite
def _pack_inputs(draw):
    """Per-slot pending-token lists with positions and decode flags, plus a
    budget >= slots and an optional prefill cap."""
    S = draw(st.integers(1, 6))
    lanes = draw(st.lists(
        st.tuples(st.integers(0, 12), st.booleans(), st.integers(0, 100)),
        min_size=S, max_size=S))
    lists, positions, flags = [], [], []
    for n, dec, pos in lanes:
        dec = dec and n > 0
        n = 1 if dec else n          # decode lanes carry exactly one token
        lists.append(list(range(pos, pos + n)))
        positions.append(pos)
        flags.append(dec)
    budget = draw(st.integers(S, 40))
    cap = draw(st.sampled_from([0, 0, 1, 2, 4, 8]))
    return lists, positions, flags, budget, cap


@given(_pack_inputs())
@settings(max_examples=100, deadline=None)
def test_pack_tokens_invariants(inp):
    """The packer's contract: budget respected, decode lanes first, per-slot
    segments contiguous with monotone positions, and a round-trip back to
    the input lists."""
    from repro.serve.scheduler import pack_tokens
    lists, positions, flags, budget, cap = inp
    pt = pack_tokens(lists, positions, flags, budget, cap)
    S, T = len(lists), len(pt.tokens)
    assert T == budget
    assert pt.n_live == int(pt.n_taken.sum()) <= budget
    n_decode = sum(1 for i in range(S) if flags[i] and lists[i])
    if cap:
        assert pt.n_live - n_decode <= cap       # prefill tokens capped
    # decode lanes always packed, exactly one token, BEFORE prefill tokens
    for i in range(S):
        if flags[i] and lists[i]:
            assert pt.n_taken[i] == 1
    decode_idx = [t for t in range(pt.n_live) if flags[pt.tok_slot[t]]]
    assert decode_idx == list(range(len(decode_idx)))
    # liveness: uncapped, every non-empty lane advances (budget >= slots)
    if not cap:
        for i in range(S):
            assert (pt.n_taken[i] > 0) == bool(lists[i])
    # padding tail is inert; live region round-trips the inputs
    assert np.all(pt.tok_pos[pt.n_live:] == -1)
    assert np.all(pt.tok_pos[:pt.n_live] >= 0)
    for i in range(S):
        n = int(pt.n_taken[i])
        sel = np.nonzero(pt.tok_slot[:pt.n_live] == i)[0]
        assert len(sel) == n
        assert n <= len(lists[i])
        if n == 0:
            assert pt.seg_last[i] == -1
            continue
        assert np.array_equal(sel, np.arange(sel[0], sel[0] + n))  # contiguous
        assert pt.seg_last[i] == sel[-1]
        assert np.array_equal(pt.tok_pos[sel],
                              positions[i] + np.arange(n))  # monotone
        assert list(pt.tokens[sel]) == lists[i][:n]          # round-trip


@given(st.integers(1, 6), st.integers(0, 50), st.integers(1, 3))
@settings(max_examples=40, deadline=None)
def test_pack_tokens_round_robin_liveness(S, t0, cap):
    """Rotation fairness: under sustained budget pressure (every tick can
    grant only ``cap`` prefill tokens), advancing ``rotate`` by one per
    tick must reach EVERY pending prefill lane within ``S`` consecutive
    ticks.  The pre-rotation packer granted from slot 0 in fixed order and
    starved the high-numbered lanes for as long as the pressure lasted."""
    from repro.serve.scheduler import pack_tokens
    lists = [list(range(100, 140)) for _ in range(S)]
    positions, flags = [0] * S, [False] * S
    advanced = set()
    for t in range(t0, t0 + S):
        pt = pack_tokens(lists, positions, flags, budget=max(S, cap),
                         prefill_cap=cap, rotate=t)
        advanced |= {i for i in range(S) if pt.n_taken[i] > 0}
    assert advanced == set(range(S))


@given(st.lists(st.integers(4, 12), min_size=2, max_size=3),
       st.integers(0, 2 ** 16))
@settings(max_examples=6, deadline=None)
def test_packed_tick_engine_matches_dense_oracle(prompt_lens, seed):
    """Random ragged prompts through the token-PACKED-tick engine on a
    page-starved pool (3 slots competing for 4 pages, so long draws preempt
    and re-admit): every request's greedy tokens must equal the dense
    full-forward oracle token-for-token — the serving invariant with the
    one-dispatch-per-tick flat-buffer program, preemption and re-prefill in
    the loop."""
    from repro.models import model as M
    from repro.serve.scheduler import EngineConfig, PagedEngine, ServeRequest
    cfg, params = _dual_oracle_cfg_params()
    max_new = 3
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, n) for n in prompt_lens]

    def oracle(prompt):
        toks = list(prompt)
        for _ in range(max_new):
            lg, _, _ = M.forward(params, cfg,
                                 {"tokens": jnp.asarray([toks])}, "train")
            toks.append(int(jnp.argmax(lg[0, -1])))
        return toks[len(prompt):]

    eng = PagedEngine(cfg, params, EngineConfig(
        page_size=8, num_pages=5, slots=3, prefill_chunk=8, max_seq=64))
    for i, p in enumerate(prompts):
        eng.submit(ServeRequest(rid=i, prompt=p, max_new=max_new))
    done = {r.rid: r for r in eng.run()}
    assert eng.stats()["dispatches_per_tick"] == 1.0
    for i, p in enumerate(prompts):
        assert not done[i].truncated
        assert done[i].generated == oracle(p), (
            prompt_lens, seed, i, eng.stats()["preemptions"])


@given(st.integers(0, 1000))
@settings(**SETTINGS)
def test_data_pipeline_deterministic(step):
    ds1 = SyntheticMarkov(256, 32, 4, seed=3)
    ds2 = SyntheticMarkov(256, 32, 4, seed=3)
    b1, b2 = ds1.batch_at(step), ds2.batch_at(step)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 256


def test_markov_stream_is_learnable_structure():
    """Bigram predictability: next token must be one of `branching`
    successors of the current token."""
    ds = SyntheticMarkov(128, 64, 4, seed=1, branching=4)
    b = ds.batch_at(0)["tokens"]
    for row in b:
        for t in range(1, len(row)):
            assert row[t] in ds.table[row[t - 1]]
