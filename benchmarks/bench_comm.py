"""Paper Fig 2 / Fig 6 / Fig 7 (structural): per-block TP all-reduce counts
and bytes, measured on the REAL ``DecoderLM`` block stack lowered through
``models/model.py::decoder_stack_tp`` (the production shard_map partial-sum
path — the toy duplicate-weight stack is gone).  ``hlo_cost.analyze`` is
while-loop aware, so the scanned layers count once per layer and the
fal/preln all-reduce-bytes ratio must land on the paper's (L+1)/(2L):
fal pays one collective per steady-state block plus block 0's extra
first-attention assemble, preln pays two per block.

Run in a subprocess-free way by forcing host devices BEFORE jax import (the
harness in run.py does this)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import hlo_cost
from repro.configs.base import get_config
from repro.models import model as M
from repro.optim import grad_compress

N_LAYERS = 8


def bench(csv):
    assert len(jax.devices()) >= 8, "run via benchmarks.run (forces devices)"
    mesh = jax.make_mesh((8,), ("model",))
    pctx = {"mesh": mesh, "data_axes": (), "model_axis": "model",
            "tp": "explicit"}
    cfg0 = get_config("llama3.2-3b").reduced().replace(
        n_layers=N_LAYERS, n_heads=8, n_kv_heads=8)
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg0.d_model))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    rows = {}
    for mode in ("preln", "parallel", "fal", "falplus"):
        cfg = cfg0.replace(connection=mode)
        params = M.init_params(jax.random.PRNGKey(0), cfg)

        def fwd(p, x, cfg=cfg):
            return M.decoder_stack_tp(p, cfg, x, positions, pctx)[0]

        t0 = time.time()
        txt = jax.jit(fwd).lower(params, x).compile().as_text()
        lower_s = time.time() - t0
        r = hlo_cost.analyze(txt)
        ar = r["collectives"].get("all-reduce", {"bytes": 0, "count": 0})
        rows[mode] = {"count": ar["count"], "bytes": ar["bytes"]}
        csv(f"comm_fig2_{mode}", lower_s * 1e6,
            f"allreduce_count={ar['count']:.0f};bytes={ar['bytes']:.0f}")
    # the paper's claim: fal ~ half of preln (steady state; block0 pays one
    # extra assemble -> (L+1)/(2L))
    ratio = rows["fal"]["bytes"] / max(rows["preln"]["bytes"], 1)
    expected = (N_LAYERS + 1) / (2 * N_LAYERS)
    csv("comm_fig2_ratio_fal_over_preln", 0, f"{ratio:.3f}")
    csv("comm_fig2_ratio_expected", 0, f"{expected:.3f}")
    assert abs(ratio - expected) < 0.02, (
        f"DecoderLM fal/preln all-reduce bytes {ratio:.3f} != "
        f"(L+1)/(2L) = {expected:.3f}")

    # Fig 7: gradient-compression payloads (lossy baselines)
    payloads = {}
    g = {"w%d" % i: jax.random.normal(jax.random.PRNGKey(i), (256, 256))
         for i in range(4)}
    for method in ("none", "int8", "lowrank"):
        b = grad_compress.compressed_bytes(g, method)
        payloads[method] = b
        csv(f"comm_fig7_payload_{method}", 0, str(b))

    return {"model": cfg0.arch_id, "n_layers": N_LAYERS,
            "batch": B, "seq": S, "d_model": cfg0.d_model,
            "allreduce_per_mode": rows,
            "ratio_fal_over_preln": ratio, "ratio_expected": expected,
            "fig7_payload_bytes": payloads}
