"""Paper Fig 2 / Fig 6 / Fig 7 (structural): per-block TP collective counts
and bytes for preln vs parallel vs fal vs falplus, plus the lossy
gradient-compression payload comparison.

Run in a subprocess-free way by forcing host devices BEFORE jax import (the
harness in run.py does this)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import hlo_cost
from repro.core import tp
from repro.optim import grad_compress


def bench(csv):
    assert len(jax.devices()) >= 8, "run via benchmarks.run (forces devices)"
    mesh = jax.make_mesh((8,), ("model",))
    n_layers, d, d_ff, heads = 8, 256, 1024, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, d))
    rows = {}
    for mode in ("preln", "parallel", "fal", "falplus"):
        init, fwd = tp.make_tp_forward(mesh, n_layers, d, d_ff, heads, mode)
        p = init(jax.random.PRNGKey(0))
        t0 = time.time()
        txt = fwd.lower(p, x).compile().as_text()
        lower_s = time.time() - t0
        r = hlo_cost.analyze(txt)
        ar = r["collectives"].get("all-reduce", {"bytes": 0, "count": 0})
        rows[mode] = ar
        csv(f"comm_fig2_{mode}", lower_s * 1e6,
            f"allreduce_count={ar['count']:.0f};bytes={ar['bytes']:.0f}")
    # the paper's claim: fal ~ half of preln (steady state; block0 pays one
    # extra assemble -> (L+1)/(2L))
    ratio = rows["fal"]["bytes"] / max(rows["preln"]["bytes"], 1)
    csv("comm_fig2_ratio_fal_over_preln", 0, f"{ratio:.3f}")
    expected = (n_layers + 1) / (2 * n_layers)
    csv("comm_fig2_ratio_expected", 0, f"{expected:.3f}")

    # Fig 7: gradient-compression payloads (lossy baselines)
    g = {"w%d" % i: jax.random.normal(jax.random.PRNGKey(i), (256, 256))
         for i in range(4)}
    for method in ("none", "int8", "lowrank"):
        b = grad_compress.compressed_bytes(g, method)
        csv(f"comm_fig7_payload_{method}", 0, str(b))
