"""Paper Fig 2 / Fig 6 / Fig 7 (structural): per-block TP collective counts
and bytes, measured on the REAL ``DecoderLM`` block stack lowered through
``models/model.py::decoder_stack_tp`` (the production shard_map partial-sum
path — the toy duplicate-weight stack is gone).  ``hlo_cost.analyze`` is
while-loop aware, so the scanned layers count once per layer and the
fal/preln all-reduce-bytes ratio must land on the paper's (L+1)/(2L):
fal pays one collective per steady-state block plus block 0's extra
first-attention assemble, preln pays two per block.

With ``sp=True`` the same modes are additionally lowered under the
sequence-parallel ``ExecutionPlan`` (Megatron-SP LN regions) and the bench
asserts the layout's contract on the HLO:

  * reduce-op count is preserved — every replicated all-reduce becomes
    exactly one reduce-scatter (block 0's first-attention export stays the
    one true all-reduce);
  * reduce bytes shrink by exactly tp_size —
    ar_bytes_sp + tp * rs_bytes_sp == ar_bytes_replicated.

Run in a subprocess-free way by forcing host devices BEFORE jax import (the
harness in run.py does this)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import hlo_cost
from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan
from repro.models import model as M
from repro.optim import grad_compress

N_LAYERS = 8
TP = 8


def _collect(txt):
    r = hlo_cost.analyze(txt)["collectives"]
    zero = {"bytes": 0, "count": 0}
    return {op: r.get(op, zero) for op in
            ("all-reduce", "reduce-scatter", "all-gather")}


def bench(csv, sp=False):
    assert len(jax.devices()) >= TP, "run via benchmarks.run (forces devices)"
    mesh = jax.make_mesh((TP,), ("model",))
    cfg0 = get_config("llama3.2-3b").reduced().replace(
        n_layers=N_LAYERS, n_heads=8, n_kv_heads=8)
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg0.d_model))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    rows, sp_rows = {}, {}
    for mode in ("preln", "parallel", "fal", "falplus"):
        cfg = cfg0.replace(connection=mode)
        params = M.init_params(jax.random.PRNGKey(0), cfg)

        def lower(plan, cfg=cfg, params=params):
            def fwd(p, x):
                return M.decoder_stack_tp(p, cfg, x, positions, plan)[0]
            t0 = time.time()
            txt = jax.jit(fwd).lower(params, x).compile().as_text()
            return _collect(txt), time.time() - t0

        plan = ExecutionPlan.from_mesh(mesh, tp="explicit").validate(cfg)
        c, lower_s = lower(plan)
        ar = c["all-reduce"]
        rows[mode] = {"count": ar["count"], "bytes": ar["bytes"]}
        csv(f"comm_fig2_{mode}", lower_s * 1e6,
            f"allreduce_count={ar['count']:.0f};bytes={ar['bytes']:.0f}")

        if sp:
            plan_sp = ExecutionPlan.from_mesh(mesh, tp="explicit",
                                              sp=True).validate(cfg)
            c_sp, lower_s = lower(plan_sp)
            ar_sp, rs, ag = (c_sp["all-reduce"], c_sp["reduce-scatter"],
                             c_sp["all-gather"])
            sp_rows[mode] = {
                "allreduce": dict(count=ar_sp["count"], bytes=ar_sp["bytes"]),
                "reduce_scatter": dict(count=rs["count"], bytes=rs["bytes"]),
                "all_gather": dict(count=ag["count"], bytes=ag["bytes"]),
            }
            csv(f"comm_sp_{mode}", lower_s * 1e6,
                f"rs_bytes={rs['bytes']:.0f};ag_bytes={ag['bytes']:.0f};"
                f"ar_bytes={ar_sp['bytes']:.0f}")
            # the SP contract: reduce-op count preserved, reduce bytes / tp
            assert ar_sp["count"] + rs["count"] == ar["count"], \
                (mode, c_sp, ar)
            assert ar_sp["bytes"] + TP * rs["bytes"] == ar["bytes"], (
                f"{mode}: SP reduce bytes not cut by tp={TP}: "
                f"ar_sp={ar_sp['bytes']} + {TP}*rs={rs['bytes']} != "
                f"ar_replicated={ar['bytes']}")
            csv(f"comm_sp_{mode}_bytes_reduction", 0,
                f"{ar['bytes'] / max(rs['bytes'] + ar_sp['bytes'], 1):.3f}")

    # the paper's claim: fal ~ half of preln (steady state; block0 pays one
    # extra assemble -> (L+1)/(2L))
    ratio = rows["fal"]["bytes"] / max(rows["preln"]["bytes"], 1)
    expected = (N_LAYERS + 1) / (2 * N_LAYERS)
    csv("comm_fig2_ratio_fal_over_preln", 0, f"{ratio:.3f}")
    csv("comm_fig2_ratio_expected", 0, f"{expected:.3f}")
    assert abs(ratio - expected) < 0.02, (
        f"DecoderLM fal/preln all-reduce bytes {ratio:.3f} != "
        f"(L+1)/(2L) = {expected:.3f}")

    # Fig 7: gradient-compression payloads (lossy baselines)
    payloads = {}
    g = {"w%d" % i: jax.random.normal(jax.random.PRNGKey(i), (256, 256))
         for i in range(4)}
    for method in ("none", "int8", "lowrank"):
        b = grad_compress.compressed_bytes(g, method)
        payloads[method] = b
        csv(f"comm_fig7_payload_{method}", 0, str(b))

    # Measured TP gradient wire bytes: lower value_and_grad of the fal stack
    # per ExecutionPlan.grad_compress method and read per-device ring-model
    # payload off the compiled HLO (core/tp.py::collective_payload_bytes —
    # NOT output-shape bytes, which would misrank the int8 all_to_all/
    # all_gather exchange).  Gradient payload = payload(grad HLO) −
    # payload(fwd HLO): the backward cotangent reductions only.  The small
    # exact residue under compression is the LN parameter-gradient psums
    # shard_map's transpose inserts for replicated params.
    from repro.core.tp import collective_payload_bytes
    cfg_g = cfg0.replace(connection="fal")
    params_g = M.init_params(jax.random.PRNGKey(0), cfg_g)
    B_G, S_G = 4, 64            # training-shaped batch: activation cotangents
    x_g = jax.random.normal(jax.random.PRNGKey(2), (B_G, S_G, cfg0.d_model))
    pos_g = jnp.broadcast_to(jnp.arange(S_G)[None], (B_G, S_G))
    grad_payloads, fwd_payload = {}, 0
    for method in grad_compress.GRAD_COMPRESS_METHODS:
        plan_g = ExecutionPlan.from_mesh(
            mesh, tp="explicit", grad_compress=method).validate(cfg_g)

        def loss(p, xx, plan=plan_g):
            y = M.decoder_stack_tp(p, cfg_g, xx, pos_g, plan)[0]
            return jnp.mean(y * y)

        t0 = time.time()
        hlo_f = jax.jit(loss).lower(params_g, x_g).compile().as_text()
        hlo_g = jax.jit(jax.value_and_grad(loss)).lower(
            params_g, x_g).compile().as_text()
        lower_s = time.time() - t0
        pf = sum(collective_payload_bytes(hlo_f, TP).values())
        pg = sum(collective_payload_bytes(hlo_g, TP).values())
        grad_payloads[method] = pg - pf
        if method == "none":
            fwd_payload = pf
        csv(f"comm_grad_payload_{method}", lower_s * 1e6,
            f"grad_bytes={pg - pf};fwd_bytes={pf}")
    assert grad_payloads["int8"] <= 0.3 * grad_payloads["none"], (
        f"grad_compress=int8 gradient payload not <=0.3x of none: "
        f"{grad_payloads}")
    assert grad_payloads["lowrank"] < grad_payloads["none"], grad_payloads
    csv("comm_grad_payload_ratio_int8_over_none", 0,
        f"{grad_payloads['int8'] / max(grad_payloads['none'], 1):.3f}")

    return {"model": cfg0.arch_id, "n_layers": N_LAYERS, "tp_size": TP,
            "batch": B, "seq": S, "d_model": cfg0.d_model,
            "allreduce_per_mode": rows,
            "sp": sp_rows,
            "ratio_fal_over_preln": ratio, "ratio_expected": expected,
            "fig7_payload_bytes": payloads,
            "grad_payload_bytes": grad_payloads,
            "grad_payload_fwd_bytes": fwd_payload}
