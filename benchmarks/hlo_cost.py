"""While-loop-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE
(verified empirically: a lax.scan of 8 matmuls reports 1/8 the unrolled
FLOPs).  Our layer stacks, microbatch accumulation and attention q-block
loops are all while loops, so the roofline needs trip-count-aware totals.

This module parses post-optimization HLO text:
  * computations + their instructions,
  * ``while`` trip counts (from the canonical `compare(iv, constant)`
    condition),
  * dot FLOPs (2 * prod(result) * prod(contracting dims)),
  * collective payload bytes by op kind + replica-group size,
  * approximate HBM traffic: sum of operand+result bytes of top-level
    (post-fusion) instructions.

Callgraph evaluation multiplies each computation's cost by the product of
enclosing trip counts.  Fusion/call/conditional multiply by 1.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DT_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
             "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
             "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*{\s*$")
_CALLED_ONE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_CALLED_MANY = re.compile(r"branch_computations=\{([^}]*)\}")


def _called_names(line):
    out = list(_CALLED_ONE.findall(line))
    for grp in _CALLED_MANY.findall(line):
        out += [nm.strip().lstrip("%") for nm in grp.split(",") if nm.strip()]
    return out


def _shape_bytes(dtype, dims):
    n = 1
    for d in dims:
        n *= d
    return n * _DT_BYTES.get(dtype, 4)


def _parse_shapes(text):
    """All shapes appearing in a line -> [(dtype, dims, bytes)]."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DT_BYTES:
            continue
        dd = [int(x) for x in dims.split(",") if x] or [1]
        out.append((dt, dd, _shape_bytes(dt, dd)))
    return out


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    line: str
    result_bytes: int
    result_dims: list
    result_dtype: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    symbols: dict  # instr name -> (dtype, dims)


def parse_hlo(text: str):
    comps = {}
    cur = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_START.match(line)
            if m and "{" in line:
                cur = Computation(m.group(1), [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        # result shape: either a (possibly commented) tuple "( ... )" or a
        # single space-free shape token; then the op name before its "(".
        m = re.match(r"(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([\w\-]+)\(", line)
        if not m:
            continue
        name, shape_part, op = m.groups()
        shapes = _parse_shapes(shape_part)
        rb = sum(s[2] for s in shapes)
        dims = shapes[0][1] if shapes else [1]
        dt = shapes[0][0] if shapes else "f32"
        cur.instrs.append(Instr(name, op, line, rb, dims, dt))
        if shapes:
            cur.symbols[name] = (dt, dims)
    return comps


def _while_trips(ins_line, comps):
    """Prefer XLA's own annotation; fall back to condition parsing."""
    m = re.search(r'known_trip_count[":{\s]+n[":\s]+(\d+)', ins_line)
    if m:
        return max(int(m.group(1)), 1)
    mc = re.search(r"condition=%?([\w\.\-]+)", ins_line)
    if mc and mc.group(1) in comps:
        return _trip_count(comps[mc.group(1)])
    return 1


def _trip_count(cond_comp: Computation):
    """Canonical XLA loop: condition compares induction var with a constant
    (direction=LT).  Returns the largest plausible constant, else 1."""
    consts = {}
    for ins in cond_comp.instrs:
        m = re.search(r"constant\((-?\d+)\)", ins.line)
        if m:
            consts[ins.name] = int(m.group(1))
    for ins in cond_comp.instrs:
        if ins.op == "compare" and "direction=LT" in ins.line:
            ops = re.findall(r"%([\w\.\-]+)", ins.line.split("compare(")[1])
            for o in ops:
                if o in consts:
                    return max(consts[o], 1)
    return 1


def _dot_flops(ins: Instr, comp: Computation):
    """FLOPs = 2 * prod(result dims) * prod(lhs contracting dims).

    The lhs operand is either inline-typed ("dot(f32[4,128]{1,0} %x, ...)",
    newer XLA text) — parse the shape directly — or a bare name reference
    ("dot(%x, ...)") resolved via the computation's symbol table."""
    line = ins.line
    m_ops = re.search(r"\b(?:dot|convolution)\(\s*([^)]*)\)", line)
    if not m_ops:
        return 0
    ops_str = m_ops.group(1)
    lhs = None
    m_shape = _SHAPE_RE.match(ops_str)
    if m_shape and m_shape.group(1) in _DT_BYTES:
        dims = [int(x) for x in m_shape.group(2).split(",") if x] or [1]
        lhs = (m_shape.group(1), dims)
    else:
        m_name = re.match(r"%?([\w\.\-]+)", ops_str)
        if m_name:
            lhs = comp.symbols.get(m_name.group(1))
    if lhs is None:
        return 0
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else \
        [len(lhs[1]) - 1]
    k = 1
    for c in cdims:
        if c < len(lhs[1]):
            k *= lhs[1][c]
    rn = 1
    for d in ins.result_dims:
        rn *= d
    return 2 * rn * k


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _group_size(line, default=1):
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [ngroups, group_size]
        return int(m.group(2))
    return default


def analyze(text: str):
    """Returns dict with loop-aware totals:
      flops            — dot FLOPs (program-wide, whole array = all devices)
      hbm_bytes        — approx HBM traffic (top-level instr operands+results)
      collectives      — {op: {"bytes": payload, "count": n, "group": max}}
    """
    comps = parse_hlo(text)
    entry = None
    for name in comps:
        pass
    # find entry: computation not called by anyone
    called = set()
    for c in comps.values():
        for ins in c.instrs:
            called.update(_called_names(ins.line))
    entries = [c for c in comps.values() if c.name not in called]
    entry = max(entries, key=lambda c: len(c.instrs)) if entries else \
        max(comps.values(), key=lambda c: len(c.instrs))

    flops = defaultdict(float)
    hbm = defaultdict(float)
    coll = defaultdict(lambda: {"bytes": 0.0, "count": 0.0, "group": 1})

    def visit(comp: Computation, mult: float, top: bool, seen):
        if comp.name in seen:
            return
        seen = seen | {comp.name}
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                flops[comp.name] += _dot_flops(ins, comp) * mult
            if top or True:
                # HBM traffic approximation: count operands+results of
                # non-trivial top-level ops (fusion boundaries)
                pass
            if ins.op in _COLLECTIVES or \
                    any(ins.op == c + "-start" for c in _COLLECTIVES):
                base = ins.op.replace("-start", "")
                if base == "all-to-all" and "(" in ins.line:
                    pass
                coll[base]["bytes"] += ins.result_bytes * mult
                coll[base]["count"] += mult
                coll[base]["group"] = max(coll[base]["group"],
                                          _group_size(ins.line))
            # recurse
            if ins.op == "while":
                m = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if m and m.group(1) in comps:
                    visit(comps[m.group(1)],
                          mult * _while_trips(ins.line, comps), False, seen)
            elif ins.op in ("fusion", "call", "custom-call", "map",
                            "reduce", "reduce-window", "scatter", "sort",
                            "conditional", "async-start"):
                for nm in _called_names(ins.line):
                    if nm in comps:
                        visit(comps[nm], mult, False, seen)

    visit(entry, 1.0, True, frozenset())

    # HBM traffic: entry-level pass with loop awareness — approximate as
    # result bytes of every instruction in every computation × multiplier.
    hbm_total = 0.0

    def visit_hbm(comp, mult, seen):
        nonlocal hbm_total
        if comp.name in seen:
            return
        seen = seen | {comp.name}
        for ins in comp.instrs:
            if ins.op in ("fusion", "dot", "convolution", "scatter",
                          "gather", "reduce", "sort", "transpose", "copy",
                          "dynamic-update-slice", "dynamic-slice",
                          *(c for c in _COLLECTIVES)):
                if "dynamic-update-slice" in ins.name \
                        or ins.op == "dynamic-update-slice":
                    # scan-stash pattern: XLA updates the buffer IN PLACE —
                    # per iteration only the slice moves, so the loop total
                    # is ONE full buffer traversal, not trips x buffer.
                    hbm_total += ins.result_bytes * 2
                else:
                    hbm_total += ins.result_bytes * mult * 2  # read+write
            if ins.op == "while":
                m = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if m and m.group(1) in comps:
                    visit_hbm(comps[m.group(1)],
                              mult * _while_trips(ins.line, comps), seen)

    visit_hbm(entry, 1.0, frozenset())

    return {
        "flops": sum(flops.values()),
        "hbm_bytes": hbm_total,
        "collectives": {k: dict(v) for k, v in coll.items()},
    }
