"""Roofline derivation from dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (per device; post-SPMD HLO shapes are already per-device):
  compute_s    = dot_FLOPs_dev / PEAK_FLOPS
  memory_s     = HBM_bytes_dev / HBM_BW
  collective_s = sum_op payload_dev * alg_factor(op, group) / ICI_BW

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per training step;
for decode, D = tokens decoded per step (= batch).  The ratio
MODEL_FLOPS / (3 * dot_FLOPs_total) — fwd+bwd dot flops ~ 3x fwd — catches
remat/redundancy waste (reported as useful_fraction).
"""
from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks import hlo_cost

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link

_ALG_FACTOR = {
    "all-reduce": lambda n: 2 * (n - 1) / max(n, 1),
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: (n - 1) / max(n, 1),
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


def roofline_terms(hlo_text, *, model_flops_per_device=None):
    r = hlo_cost.analyze(hlo_text)
    compute_s = r["flops"] / PEAK_FLOPS
    memory_s = r["hbm_bytes"] / HBM_BW
    coll_s = 0.0
    for op, d in r["collectives"].items():
        coll_s += d["bytes"] * _ALG_FACTOR[op](d["group"]) / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s,
             "hlo_flops_dev": r["flops"], "hbm_bytes_dev": r["hbm_bytes"],
             "collectives": r["collectives"]}
    terms["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                            key=lambda k: terms[k])
    if model_flops_per_device:
        terms["model_flops_dev"] = model_flops_per_device
        terms["useful_fraction"] = (model_flops_per_device /
                                    max(r["flops"], 1.0))
    return terms


# --------------------------------------------------------------------------- #
def param_count(cfg):
    """Total / active param counts (approx, embeddings excluded from 6ND)."""
    d, L = cfg.d_model, cfg.n_layers
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * d
        per = d * (2 * d_in + 2 * cfg.ssm_state + d_in // cfg.ssm_head_dim) \
            + d_in * d
        return per * L, per * L
    Dh = cfg.resolved_head_dim
    attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * Dh \
        + cfg.n_heads * Dh * d
    if cfg.use_mla:
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        attn = (d * cfg.q_lora_rank
                + cfg.q_lora_rank * cfg.n_heads * (dn + dr)
                + d * (cfg.kv_lora_rank + dr)
                + cfg.kv_lora_rank * cfg.n_heads * (dn + dv)
                + cfg.n_heads * dv * d)
    mlp_mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    if cfg.n_experts:
        dense_ff = cfg.dense_d_ff or cfg.d_ff
        n_dense = cfg.first_dense_layers
        n_moe = L - n_dense
        moe_total = n_moe * (cfg.n_experts * mlp_mult * d * cfg.moe_d_ff
                             + cfg.n_shared_experts * mlp_mult * d * cfg.moe_d_ff)
        moe_active = n_moe * ((cfg.top_k + cfg.n_shared_experts)
                              * mlp_mult * d * cfg.moe_d_ff)
        total = L * attn + n_dense * mlp_mult * d * dense_ff + moe_total
        active = L * attn + n_dense * mlp_mult * d * dense_ff + moe_active
        return total, active
    per = attn + mlp_mult * d * cfg.d_ff
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        mamba_per = d * (2 * d_in + 2 * cfg.ssm_state
                         + d_in // cfg.ssm_head_dim) + d_in * d
        n_shared = L // max(cfg.attn_every, 1)
        total = L * mamba_per + (attn + mlp_mult * d * cfg.d_ff) \
            + n_shared * 2 * d * d
        return total, total
    n_layers = L + (cfg.n_enc_layers if cfg.is_encoder_decoder else 0)
    return n_layers * per, n_layers * per


def model_flops(cfg, shape_cfg, chips):
    """6*N_active*D per step, per device."""
    _, active = param_count(cfg)
    if shape_cfg.mode == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6 * active * tokens / chips
    if shape_cfg.mode == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2 * active * tokens / chips
    return 2 * active * shape_cfg.global_batch / chips  # decode: 1 tok/seq


def main():
    """Summarise every dry-run HLO in experiments/dryrun into a table."""
    sys.path.insert(0, "src")
    from repro.configs.base import INPUT_SHAPES, get_config
    out = []
    for hlo_path in sorted(glob.glob("experiments/dryrun/*.hlo")):
        tag = os.path.basename(hlo_path)[:-4]
        arch = shape = meshk = None
        for s in INPUT_SHAPES:
            if f"_{s}_" in tag:
                arch, rest = tag.split(f"_{s}_", 1)
                shape, meshk = s, rest.split("_")[0]
                break
        if shape is None or "_" in (meshk or "_"):
            continue  # connection-suffixed perf runs are analysed separately
        chips = 512 if meshk == "multi" else 256
        cfg = get_config(arch)
        mf = model_flops(cfg, INPUT_SHAPES[shape], chips)
        with open(hlo_path) as f:
            terms = roofline_terms(f.read(), model_flops_per_device=mf)
        row = {"arch": arch, "shape": shape, "mesh": meshk, **{
            k: terms[k] for k in ("compute_s", "memory_s", "collective_s",
                                  "dominant", "useful_fraction",
                                  "hlo_flops_dev")}}
        out.append(row)
        print(f"{arch:24s} {shape:12s} {meshk:6s} "
              f"C={terms['compute_s']*1e3:9.3f}ms "
              f"M={terms['memory_s']*1e3:9.3f}ms "
              f"N={terms['collective_s']*1e3:9.3f}ms "
              f"dom={terms['dominant'][:-2]:10s} "
              f"useful={terms.get('useful_fraction', 0):.2f}")
    with open("experiments/roofline.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
