"""Benchmark harness — one bench per paper table/figure.
Prints ``name,us_per_call,derived`` CSV; ``--json`` additionally writes
``BENCH_<suite>.json`` for suites that return structured results (the
machine-readable perf trajectory).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--json]
"""
import os

from benchmarks.hostdev import force_host_devices

force_host_devices()     # must precede the first jax import (see hostdev)

import argparse
import json
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer training benches")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<suite>.json for suites returning data")
    ap.add_argument("--json-dir", default=".",
                    help="directory for the --json output files")
    ap.add_argument("--sp", action="store_true",
                    help="comm suite: also lower the sequence-parallel "
                         "ExecutionPlan per mode and assert the tp_size "
                         "reduce-bytes reduction")
    ap.add_argument("--dual", action="store_true",
                    help="serving suite: also bench the dual-branch "
                         "(MHA||MLP) engine, assert token identity vs the "
                         "sequential path and the no-extra-collectives "
                         "structural gate under explicit TP")
    ap.add_argument("--trace", action="store_true",
                    help="serving suite: re-run the burst workload with the "
                         "span tracer attached, write a Chrome trace "
                         "(TRACE_serving.json) and record the tok/s "
                         "overhead")
    args = ap.parse_args()

    def csv(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    from benchmarks import (bench_comm, bench_inference, bench_motivation,
                            bench_quality, bench_serving, bench_throughput)

    steps = 300 if args.full else 100
    suites = {
        "comm": lambda: bench_comm.bench(csv, sp=args.sp),
        "throughput": lambda: bench_throughput.bench(csv),
        "quality": lambda: bench_quality.bench(csv, steps=steps),
        "quality_compress": lambda: bench_quality.bench_compress(
            csv, steps=max(steps * 2 // 3, 50)),
        "quality_depth": lambda: bench_quality.bench_depth_scaling(
            csv, steps=max(steps * 2 // 3, 50)),
        "motivation": lambda: bench_motivation.bench(csv, steps=steps),
        "inference": lambda: bench_inference.bench(csv),
        "serving": lambda: bench_serving.bench(
            csv, dual=args.dual, trace=args.trace,
            trace_out=os.path.join(args.json_dir, "TRACE_serving.json")),
    }
    failures = 0
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        print(f"# suite {name}", flush=True)
        try:
            data = fn()
            if args.json and isinstance(data, dict):
                # every emitted BENCH_*.json carries the run's provenance:
                # git sha, jax/device versions, and the RUNTIME-measured
                # kernel dispatch path per call site (kernels.ops registry)
                from repro.kernels.ops import dispatch_paths
                from repro.obs.runmeta import run_metadata
                data["meta"] = run_metadata(
                    timestamp=time.time(),
                    dispatch_paths=dispatch_paths() or None)
                path = os.path.join(args.json_dir, f"BENCH_{name}.json")
                with open(path, "w") as f:
                    json.dump(data, f, indent=1, sort_keys=True)
                print(f"# wrote {path}", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0,SUITE_FAILED", flush=True)
        print(f"# suite {name} done in {time.time()-t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
