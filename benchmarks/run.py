"""Benchmark harness — one bench per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
"""
import os

# bench_comm needs a model-axis mesh; everything else is happy with it too.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer training benches")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    def csv(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    from benchmarks import (bench_comm, bench_inference, bench_motivation,
                            bench_quality, bench_serving, bench_throughput)

    steps = 300 if args.full else 100
    suites = {
        "comm": lambda: bench_comm.bench(csv),
        "throughput": lambda: bench_throughput.bench(csv),
        "quality": lambda: bench_quality.bench(csv, steps=steps),
        "quality_compress": lambda: bench_quality.bench_compress(
            csv, steps=max(steps * 2 // 3, 50)),
        "quality_depth": lambda: bench_quality.bench_depth_scaling(
            csv, steps=max(steps * 2 // 3, 50)),
        "motivation": lambda: bench_motivation.bench(csv, steps=steps),
        "inference": lambda: bench_inference.bench(csv),
        "serving": lambda: bench_serving.bench(csv),
    }
    failures = 0
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        print(f"# suite {name}", flush=True)
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0,SUITE_FAILED", flush=True)
        print(f"# suite {name} done in {time.time()-t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
