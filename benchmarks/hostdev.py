"""Force >= n XLA host-platform devices BEFORE jax is imported.

bench_comm needs >= 8 host devices and the serving dual-branch structural
gate lowers on a 2-device mesh; everything else is happy with them too.
APPEND to any user-exported XLA_FLAGS — setdefault would silently drop the
forced count whenever XLA_FLAGS is already set — and RAISE a user-exported
count below ``n`` (keeping it would still fail the `len(jax.devices()) >=
n` asserts downstream).  Call this before the first ``import jax`` in every
benchmark entry point (``benchmarks.run``, standalone ``bench_serving``).
"""
import os
import re


def force_host_devices(n: int = 8) -> None:
    force = f"--xla_force_host_platform_device_count={n}"
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    if m is None:
        os.environ["XLA_FLAGS"] = (flags + " " + force).strip()
    elif int(m.group(1)) < n:
        os.environ["XLA_FLAGS"] = flags.replace(m.group(0), force)
