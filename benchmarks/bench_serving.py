"""Serving benchmark: ragged Poisson arrivals through the paged engine vs
the seed token-by-token engine — tok/s, p50/p99 request latency, per-tick
decode latency, dispatches per tick, page utilization, preemption count.

Three paged paths are timed against the seed engine on the IDENTICAL
workload (same prompts, arrival ticks, generation lengths, greedy
decoding):

  * ``paged``  — the retired two-program engine (``mixed_ticks=False``): a
    (slots, chunk) prefill dispatch then a (slots, 1) decode dispatch per
    tick;
  * ``mixed``  — the mixed-tick engine: ONE (slots, chunk) dispatch per
    tick serving prefill and decode lanes together (the chunked
    block-table kernel).  Timed on a PREFILL-BURST load (heavier Poisson
    arrivals, so most ticks carry both phases — the regime the fusion
    targets) against the two-dispatch engine on the identical workload;
    tokens are asserted identical and the ``dispatches_per_tick == 1``
    contract is asserted here.  On the padded cpu-fallback path the
    per-lane chunk columns cost real FLOPs, so the decode-only tail
    favors the (slots, 1) program — the recorded ``dispatch_path`` keeps
    that from reading as a kernel regression;
  * ``dual``   — (``--dual``) the dual-branch (MHA||MLP) engine on the
    two-program path (its fused Pallas dispatch is the C == 1 decode
    tick); asserts token identity and gates on the structural
    no-extra-collectives assertion under explicit TP.

Every engine is warmed up before timing — BOTH jitted programs for the
two-program engines, the single program for the mixed engine — and the
dispatch path actually timed (``fused-tpu`` vs ``cpu-fallback``) is
recorded next to every number so a cold/fallback run can never read as a
kernel regression.

Standalone:  PYTHONPATH=src python benchmarks/bench_serving.py [--dual]
             [--json] (writes BENCH_serving.json)
"""
from __future__ import annotations

import os

# standalone runs need the same forced host-device count benchmarks.run
# applies (the --dual structural gate lowers on a 2-device mesh); must run
# BEFORE jax import, no-op when run.py already forced >= 8
try:
    from benchmarks.hostdev import force_host_devices
except ImportError:   # plain-script invocation: benchmarks/ itself on path
    from hostdev import force_host_devices

force_host_devices()

import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.decode import ContinuousBatcher, Request
from repro.serve.scheduler import EngineConfig, PagedEngine, ServeRequest


def _dispatch_path():
    from repro.kernels.ops import _default_use_pallas
    return "fused-tpu" if _default_use_pallas() else "cpu-fallback"


def _workload(vocab, n_requests=12, seed=0, rate=0.5):
    """Poisson arrivals (exp inter-arrival, in engine ticks), ragged
    prompts, ragged generation lengths."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests)).astype(int)
    return [
        {"rid": i,
         "arrival_tick": int(arrivals[i]),
         "prompt": rng.integers(0, vocab, int(rng.integers(32, 97))),
         "max_new": int(rng.integers(8, 25))}
        for i in range(n_requests)
    ]


def _drive(submit, step, pending, active_or_queued):
    """Tick loop feeding arrivals at their scheduled tick; returns
    (wall seconds, per-request latency in ticks)."""
    tick = 0
    t0 = time.time()
    while pending or active_or_queued():
        for w in list(pending):
            if w["arrival_tick"] <= tick:
                submit(w, tick)
                pending.remove(w)
        if active_or_queued():
            step()
        tick += 1
    return time.time() - t0, tick


def _warmup(engine, mk_req):
    """Compile every jitted program the engine's config uses outside the
    timed region: the warmup request's prompt (40 tokens) exceeds the
    prefill chunk and it decodes several tokens, so the two-program engine
    traces BOTH its (B, chunk) and (B, 1) shapes and the mixed engine its
    single (B, chunk) shape — nothing is ever timed cold."""
    engine.submit(mk_req())
    engine.run()


def _lat_percentiles(samples):
    """(p50, p99) of a sorted-able sample list; (0, 0) when empty."""
    if not samples:
        return 0.0, 0.0
    s = sorted(samples)
    p50 = s[len(s) // 2]
    p99 = s[min(len(s) - 1, int(np.ceil(0.99 * len(s))) - 1)]
    return p50, p99


def _run_paged(cfg, params, work, ecfg):
    """Drive one paged-engine run over ``work``; returns (wall seconds,
    finished requests, warmup-corrected stats, per-decode-tick wall ms)."""
    eng = PagedEngine(cfg, params, ecfg)
    _warmup(eng, lambda: ServeRequest(rid=-1, prompt=np.arange(40) % cfg.vocab,
                                      max_new=4))
    # drop the warmup request from every reported stat (jit stays warm)
    eng.finished.clear()
    eng.reset_stats()

    def submit(w, tick):
        eng.submit(ServeRequest(rid=w["rid"], prompt=w["prompt"],
                                max_new=w["max_new"]))

    decode_tick_ms = []

    def step():
        # a decode lane is waiting iff some active slot has exactly one
        # pending token; on the two-program path that lane's advance is
        # head-of-line blocked behind the tick's prefill dispatch
        had_decode = any(r is not None and len(r.known()) - r.pos == 1
                         for r in eng.slots)
        t0 = time.perf_counter()
        eng.step()
        if had_decode:
            decode_tick_ms.append((time.perf_counter() - t0) * 1e3)

    dt, _ = _drive(
        submit, step, list(work),
        lambda: eng.queue or any(s is not None for s in eng.slots))
    return dt, eng.finished, eng.stats(), decode_tick_ms


def _dual_structural_gate():
    """Shared gate (core.tp.assert_dual_no_extra_collectives) on a 2-device
    mesh: dual-branch decode ticks must lower to the SAME collective counts
    as sequential ones (ONE fused all-reduce).  Returns the fal counts."""
    from repro.core import tp
    mesh = jax.make_mesh((2,), ("model",))
    return tp.assert_dual_no_extra_collectives(mesh, modes=("fal",))["fal"]


def bench(csv, dual=False):
    cfg = get_config("gpt2-117m").replace(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, d_ff=1024,
        vocab=2048, max_seq=512, dtype="float32", param_dtype="float32",
        remat=False, attn_block_q=64, attn_block_k=128, connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    max_seq, slots = 160, 4
    data = {"dispatch_path": _dispatch_path()}

    # ---- seed engine: contiguous cache, one token per tick ---------------
    work = _workload(cfg.vocab)
    seed_eng = ContinuousBatcher(cfg, params, batch_slots=slots,
                                 max_seq=max_seq)
    _warmup(seed_eng, lambda: Request(rid=-1,
                                      prompt=np.arange(40) % cfg.vocab,
                                      max_new=4))
    seed_eng.reset_stats()
    seed_done = []

    def submit_seed(w, tick):
        seed_eng.submit(Request(rid=w["rid"], prompt=w["prompt"],
                                max_new=w["max_new"]))

    dt_seed, _ = _drive(
        submit_seed, lambda: seed_done.extend(seed_eng.step()), list(work),
        lambda: seed_eng.queue or any(s is not None for s in seed_eng.slots))
    toks_seed = sum(len(r.generated) for r in seed_done)
    csv("serving_seed_engine", dt_seed * 1e6,
        f"tok_per_s={toks_seed/dt_seed:.0f};requests={len(work)}")
    data["seed"] = {"tok_per_s": toks_seed / dt_seed,
                    "requests": len(work),
                    "dispatches_per_tick":
                        seed_eng.stats()["dispatches_per_tick"]}

    # ---- paged engine (two-program path): chunked prefill + paged KV -----
    work = _workload(cfg.vocab)
    ecfg = EngineConfig(page_size=16, num_pages=48, slots=slots,
                        prefill_chunk=32, max_seq=max_seq,
                        mixed_ticks=False)
    dt, done, st, dec_ms = _run_paged(cfg, params, work, ecfg)
    toks = sum(len(r.generated) for r in done)
    lat_ticks = sorted(r.finish_tick - r.submit_tick for r in done)
    p50, p99 = _lat_percentiles(lat_ticks)
    d50, d99 = _lat_percentiles(dec_ms)
    csv("serving_paged_engine", dt * 1e6,
        f"tok_per_s={toks/dt:.0f};p50_ticks={p50};p99_ticks={p99};"
        f"decode_p50_ms={d50:.1f};decode_p99_ms={d99:.1f};"
        f"dispatches_per_tick={st['dispatches_per_tick']:.2f}")
    csv("serving_paged_pages", 0,
        f"mean_util={st['mean_page_utilization']:.2f};"
        f"peak={st['pages']['peak_in_use']};"
        f"preemptions={st['preemptions']}")
    csv("serving_prefill_speedup", 0,
        f"paged_vs_seed={dt_seed/dt:.2f};"
        f"prefill_dispatches={st['prefill_calls']};"
        f"seed_prefill_dispatches~={sum(len(w['prompt']) for w in work)}")
    assert toks == toks_seed, (toks, toks_seed)
    data["paged"] = {"tok_per_s": toks / dt, "p50_ticks": p50,
                     "p99_ticks": p99,
                     "decode_p50_ms": d50, "decode_p99_ms": d99,
                     "dispatches_per_tick": st["dispatches_per_tick"],
                     "mean_occupancy": st["mean_occupancy"],
                     "mean_page_utilization": st["mean_page_utilization"],
                     "preemptions": st["preemptions"]}
    tok_map = {r.rid: r.generated for r in done}

    # ---- mixed-tick engine: ONE (slots, chunk) dispatch per tick ---------
    # prefill-burst load: heavier arrivals + a finer chunk keep both phases
    # live in most ticks — the head-of-line regime the fusion targets; the
    # two-dispatch engine runs the IDENTICAL workload and config
    burst = dict(n_requests=16, rate=2.0)
    ecfg_burst = dataclasses.replace(ecfg, prefill_chunk=8)
    dt_t, done_t, st_t, dec_ms_t = _run_paged(
        cfg, params, _workload(cfg.vocab, **burst), ecfg_burst)
    dt_m, done_m, st_m, dec_ms_m = _run_paged(
        cfg, params, _workload(cfg.vocab, **burst),
        dataclasses.replace(ecfg_burst, mixed_ticks=True))
    toks_t = sum(len(r.generated) for r in done_t)
    toks_m = sum(len(r.generated) for r in done_m)
    assert ({r.rid: r.generated for r in done_m}
            == {r.rid: r.generated for r in done_t}), \
        "mixed-tick tokens diverged from the two-dispatch engine"
    assert st_m["dispatches_per_tick"] == 1.0, st_m
    d50_t, d99_t = _lat_percentiles(dec_ms_t)
    d50_m, d99_m = _lat_percentiles(dec_ms_m)
    p50_m, p99_m = _lat_percentiles(
        sorted(r.finish_tick - r.submit_tick for r in done_m))
    csv("serving_two_dispatch_under_burst", dt_t * 1e6,
        f"tok_per_s={toks_t/dt_t:.0f};"
        f"decode_p50_ms={d50_t:.1f};decode_p99_ms={d99_t:.1f};"
        f"dispatches_per_tick={st_t['dispatches_per_tick']:.2f}")
    csv("serving_mixed_tick_engine", dt_m * 1e6,
        f"tok_per_s={toks_m/dt_m:.0f};"
        f"decode_p50_ms={d50_m:.1f};decode_p99_ms={d99_m:.1f};"
        f"dispatches_per_tick={st_m['dispatches_per_tick']:.2f};"
        f"occupancy={st_m['mean_occupancy']:.2f};"
        f"mixed_vs_two_dispatch={dt_t/dt_m:.2f};"
        f"path={data['dispatch_path']}")
    data["mixed"] = {"tok_per_s": toks_m / dt_m,
                     "p50_ticks": p50_m, "p99_ticks": p99_m,
                     "decode_p50_ms": d50_m, "decode_p99_ms": d99_m,
                     "dispatches_per_tick": st_m["dispatches_per_tick"],
                     "mean_occupancy": st_m["mean_occupancy"],
                     "speedup_vs_two_dispatch": dt_t / dt_m,
                     "preemptions": st_m["preemptions"],
                     "dispatch_path": data["dispatch_path"],
                     "workload": {**burst,
                                  "prefill_chunk": ecfg_burst.prefill_chunk},
                     "two_dispatch": {
                         "tok_per_s": toks_t / dt_t,
                         "decode_p50_ms": d50_t, "decode_p99_ms": d99_t,
                         "dispatches_per_tick":
                             st_t["dispatches_per_tick"]}}

    if not dual:
        return data

    # ---- dual-branch engine: MHA||MLP branch-parallel decode dispatch ----
    # (two-program path: the fused Pallas dual dispatch is the C == 1
    # decode tick; _run_paged warms both programs before timing)
    work = _workload(cfg.vocab)
    dt_d, done_d, _, _ = _run_paged(cfg, params, work,
                                    dataclasses.replace(ecfg,
                                                        dual_branch=True))
    toks_d = sum(len(r.generated) for r in done_d)
    # the CPU fallback replays the sequential path's exact ops, so tokens
    # are identical request-for-request; the fused TPU kernel's tiled FFN
    # accumulation is only tolerance-close to mlp_apply, where a near-tie
    # argmax may legitimately flip — don't hard-fail there
    tok_map_d = {r.rid: r.generated for r in done_d}
    if data["dispatch_path"] == "cpu-fallback":
        assert tok_map_d == tok_map, \
            "dual-branch tokens diverged from sequential decode"
    elif tok_map_d != tok_map:
        csv("serving_dual_branch_token_drift", 0,
            f"mismatched_requests="
            f"{sum(tok_map_d[r] != tok_map[r] for r in tok_map)}")
    csv("serving_dual_branch_engine", dt_d * 1e6,
        f"tok_per_s={toks_d/dt_d:.0f};"
        f"dual_vs_sequential={dt/dt_d:.2f};"
        f"path={data['dispatch_path']}")
    data["dual"] = {"tok_per_s": toks_d / dt_d,
                    "sequential_tok_per_s": toks / dt,
                    "speedup_vs_sequential": dt / dt_d,
                    "dispatch_path": data["dispatch_path"]}

    # structural gate: no extra collectives under explicit TP
    if len(jax.devices()) >= 2:
        counts = _dual_structural_gate()
        csv("serving_dual_branch_collectives", 0,
            f"sequential={counts['sequential']};dual={counts['dual']}")
        data["dual"]["collectives"] = counts
    else:
        csv("serving_dual_branch_collectives", 0, "SKIPPED_single_device")
    return data


def main():
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--dual", action="store_true",
                    help="also bench the dual-branch engine + structural "
                         "collectives gate")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_serving.json")
    ap.add_argument("--json-dir", default=".")
    args = ap.parse_args()

    def csv(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    data = bench(csv, dual=args.dual)
    if args.json:
        path = os.path.join(args.json_dir, "BENCH_serving.json")
        with open(path, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
