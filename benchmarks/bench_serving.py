"""Serving benchmark: ragged Poisson arrivals through the paged engine vs
the seed token-by-token engine — tok/s, p50/p99 request latency, page
utilization, preemption count.

The workload is identical for both engines (same prompts, arrival ticks and
generation lengths, greedy decoding), so the delta isolates the two engine
changes: chunked batched prefill (one multi-token dispatch per chunk vs one
dispatch per prompt token) and the paged cache (pages sized to traffic vs a
contiguous (B, max_seq) reservation).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.decode import ContinuousBatcher, Request
from repro.serve.scheduler import EngineConfig, PagedEngine, ServeRequest


def _workload(vocab, n_requests=12, seed=0, rate=0.5):
    """Poisson arrivals (exp inter-arrival, in engine ticks), ragged
    prompts, ragged generation lengths."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests)).astype(int)
    return [
        {"rid": i,
         "arrival_tick": int(arrivals[i]),
         "prompt": rng.integers(0, vocab, int(rng.integers(32, 97))),
         "max_new": int(rng.integers(8, 25))}
        for i in range(n_requests)
    ]


def _drive(submit, step, pending, active_or_queued):
    """Tick loop feeding arrivals at their scheduled tick; returns
    (wall seconds, per-request latency in ticks)."""
    tick = 0
    t0 = time.time()
    while pending or active_or_queued():
        for w in list(pending):
            if w["arrival_tick"] <= tick:
                submit(w, tick)
                pending.remove(w)
        if active_or_queued():
            step()
        tick += 1
    return time.time() - t0, tick


def bench(csv):
    cfg = get_config("gpt2-117m").replace(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, d_ff=1024,
        vocab=2048, max_seq=512, dtype="float32", param_dtype="float32",
        remat=False, attn_block_q=64, attn_block_k=128, connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    max_seq, slots = 160, 4

    def warmup(engine, mk_req):
        """Compile the engine's programs outside the timed region (the paged
        engine has two traces: (B, chunk) prefill and (B, 1) decode)."""
        engine.submit(mk_req())
        engine.run()

    # ---- seed engine: contiguous cache, one token per tick ---------------
    work = _workload(cfg.vocab)
    seed_eng = ContinuousBatcher(cfg, params, batch_slots=slots,
                                 max_seq=max_seq)
    warmup(seed_eng, lambda: Request(rid=-1, prompt=np.arange(40) % cfg.vocab,
                                     max_new=4))
    seed_done = []

    def submit_seed(w, tick):
        seed_eng.submit(Request(rid=w["rid"], prompt=w["prompt"],
                                max_new=w["max_new"]))

    dt_seed, _ = _drive(
        submit_seed, lambda: seed_done.extend(seed_eng.step()), list(work),
        lambda: seed_eng.queue or any(s is not None for s in seed_eng.slots))
    toks_seed = sum(len(r.generated) for r in seed_done)
    csv("serving_seed_engine", dt_seed * 1e6,
        f"tok_per_s={toks_seed/dt_seed:.0f};requests={len(work)}")

    # ---- paged engine: chunked batched prefill + paged KV ----------------
    work = _workload(cfg.vocab)
    eng = PagedEngine(cfg, params, EngineConfig(
        page_size=16, num_pages=48, slots=slots, prefill_chunk=32,
        max_seq=max_seq))
    warmup(eng, lambda: ServeRequest(rid=-1, prompt=np.arange(40) % cfg.vocab,
                                     max_new=4))
    # drop the warmup request from every reported stat, not just the
    # request list (utilization samples, page peak, call counters)
    eng.finished.clear()
    eng._util.clear()
    eng.allocator.peak_in_use = eng.allocator.in_use
    eng.decode_calls = eng.preemptions = 0
    eng.prefill_tokens = eng.decode_tokens = 0

    pre_prefill_calls = eng.prefill_calls    # jit warm, so keep the counter

    def submit_paged(w, tick):
        eng.submit(ServeRequest(rid=w["rid"], prompt=w["prompt"],
                                max_new=w["max_new"]))

    dt, _ = _drive(
        submit_paged, eng.step, list(work),
        lambda: eng.queue or any(s is not None for s in eng.slots))
    done = eng.finished
    toks = sum(len(r.generated) for r in done)
    st = eng.stats()
    st["prefill_calls"] -= pre_prefill_calls
    lat_ticks = sorted(r.finish_tick - r.submit_tick for r in done)
    p50 = lat_ticks[len(lat_ticks) // 2]
    p99 = lat_ticks[min(len(lat_ticks) - 1,
                        int(np.ceil(0.99 * len(lat_ticks))) - 1)]
    csv("serving_paged_engine", dt * 1e6,
        f"tok_per_s={toks/dt:.0f};p50_ticks={p50};p99_ticks={p99}")
    csv("serving_paged_pages", 0,
        f"mean_util={st['mean_page_utilization']:.2f};"
        f"peak={st['pages']['peak_in_use']};"
        f"preemptions={st['preemptions']}")
    csv("serving_prefill_speedup", 0,
        f"paged_vs_seed={dt_seed/dt:.2f};"
        f"prefill_dispatches={st['prefill_calls']};"
        f"seed_prefill_dispatches~={sum(len(w['prompt']) for w in work)}")
    assert toks == toks_seed, (toks, toks_seed)
