"""Serving benchmark: ragged Poisson arrivals through the paged engine vs
the seed token-by-token engine — tok/s, p50/p99 request latency, page
utilization, preemption count.  ``--dual`` additionally runs the same
workload through the dual-branch (MHA||MLP) engine, asserts its tokens are
identical to the sequential paged run, records tok/s for BOTH paths, and
gates on the structural assertion that a dual-branch decode tick lowers to
the SAME collective counts as a sequential one under explicit TP.

The workload is identical for every engine (same prompts, arrival ticks and
generation lengths, greedy decoding), so the deltas isolate the engine
changes: chunked batched prefill vs one dispatch per prompt token, the
paged cache vs a contiguous (B, max_seq) reservation, and branch-parallel
vs serial MHA->MLP block execution.

Standalone:  PYTHONPATH=src python benchmarks/bench_serving.py [--dual]
             [--json] (writes BENCH_serving.json)
"""
from __future__ import annotations

import os

# standalone runs need the same forced host-device count benchmarks.run
# applies (the --dual structural gate lowers on a 2-device mesh); must run
# BEFORE jax import, no-op when run.py already forced >= 8
try:
    from benchmarks.hostdev import force_host_devices
except ImportError:   # plain-script invocation: benchmarks/ itself on path
    from hostdev import force_host_devices

force_host_devices()

import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.decode import ContinuousBatcher, Request
from repro.serve.scheduler import EngineConfig, PagedEngine, ServeRequest


def _workload(vocab, n_requests=12, seed=0, rate=0.5):
    """Poisson arrivals (exp inter-arrival, in engine ticks), ragged
    prompts, ragged generation lengths."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests)).astype(int)
    return [
        {"rid": i,
         "arrival_tick": int(arrivals[i]),
         "prompt": rng.integers(0, vocab, int(rng.integers(32, 97))),
         "max_new": int(rng.integers(8, 25))}
        for i in range(n_requests)
    ]


def _drive(submit, step, pending, active_or_queued):
    """Tick loop feeding arrivals at their scheduled tick; returns
    (wall seconds, per-request latency in ticks)."""
    tick = 0
    t0 = time.time()
    while pending or active_or_queued():
        for w in list(pending):
            if w["arrival_tick"] <= tick:
                submit(w, tick)
                pending.remove(w)
        if active_or_queued():
            step()
        tick += 1
    return time.time() - t0, tick


def _warmup(engine, mk_req):
    """Compile the engine's programs outside the timed region (the paged
    engine has two traces: (B, chunk) prefill and (B, 1) decode)."""
    engine.submit(mk_req())
    engine.run()


def _run_paged(cfg, params, work, ecfg):
    """Drive one paged-engine run over ``work``; returns (wall seconds,
    finished requests, warmup-corrected stats)."""
    eng = PagedEngine(cfg, params, ecfg)
    _warmup(eng, lambda: ServeRequest(rid=-1, prompt=np.arange(40) % cfg.vocab,
                                      max_new=4))
    # drop the warmup request from every reported stat, not just the
    # request list (utilization samples, page peak, call counters)
    eng.finished.clear()
    eng._util.clear()
    eng.allocator.peak_in_use = eng.allocator.in_use
    eng.decode_calls = eng.preemptions = 0
    eng.prefill_tokens = eng.decode_tokens = 0
    pre_prefill_calls = eng.prefill_calls    # jit warm, so keep the counter

    def submit(w, tick):
        eng.submit(ServeRequest(rid=w["rid"], prompt=w["prompt"],
                                max_new=w["max_new"]))

    dt, _ = _drive(
        submit, eng.step, list(work),
        lambda: eng.queue or any(s is not None for s in eng.slots))
    st = eng.stats()
    st["prefill_calls"] -= pre_prefill_calls
    return dt, eng.finished, st


def _dual_structural_gate():
    """Shared gate (core.tp.assert_dual_no_extra_collectives) on a 2-device
    mesh: dual-branch decode ticks must lower to the SAME collective counts
    as sequential ones (ONE fused all-reduce).  Returns the fal counts."""
    from repro.core import tp
    mesh = jax.make_mesh((2,), ("model",))
    return tp.assert_dual_no_extra_collectives(mesh, modes=("fal",))["fal"]


def bench(csv, dual=False):
    cfg = get_config("gpt2-117m").replace(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, d_ff=1024,
        vocab=2048, max_seq=512, dtype="float32", param_dtype="float32",
        remat=False, attn_block_q=64, attn_block_k=128, connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    max_seq, slots = 160, 4
    data = {}

    # ---- seed engine: contiguous cache, one token per tick ---------------
    work = _workload(cfg.vocab)
    seed_eng = ContinuousBatcher(cfg, params, batch_slots=slots,
                                 max_seq=max_seq)
    _warmup(seed_eng, lambda: Request(rid=-1,
                                      prompt=np.arange(40) % cfg.vocab,
                                      max_new=4))
    seed_done = []

    def submit_seed(w, tick):
        seed_eng.submit(Request(rid=w["rid"], prompt=w["prompt"],
                                max_new=w["max_new"]))

    dt_seed, _ = _drive(
        submit_seed, lambda: seed_done.extend(seed_eng.step()), list(work),
        lambda: seed_eng.queue or any(s is not None for s in seed_eng.slots))
    toks_seed = sum(len(r.generated) for r in seed_done)
    csv("serving_seed_engine", dt_seed * 1e6,
        f"tok_per_s={toks_seed/dt_seed:.0f};requests={len(work)}")
    data["seed"] = {"tok_per_s": toks_seed / dt_seed,
                    "requests": len(work)}

    # ---- paged engine: chunked batched prefill + paged KV ----------------
    work = _workload(cfg.vocab)
    ecfg = EngineConfig(page_size=16, num_pages=48, slots=slots,
                        prefill_chunk=32, max_seq=max_seq)
    dt, done, st = _run_paged(cfg, params, work, ecfg)
    toks = sum(len(r.generated) for r in done)
    lat_ticks = sorted(r.finish_tick - r.submit_tick for r in done)
    p50 = lat_ticks[len(lat_ticks) // 2]
    p99 = lat_ticks[min(len(lat_ticks) - 1,
                        int(np.ceil(0.99 * len(lat_ticks))) - 1)]
    csv("serving_paged_engine", dt * 1e6,
        f"tok_per_s={toks/dt:.0f};p50_ticks={p50};p99_ticks={p99}")
    csv("serving_paged_pages", 0,
        f"mean_util={st['mean_page_utilization']:.2f};"
        f"peak={st['pages']['peak_in_use']};"
        f"preemptions={st['preemptions']}")
    csv("serving_prefill_speedup", 0,
        f"paged_vs_seed={dt_seed/dt:.2f};"
        f"prefill_dispatches={st['prefill_calls']};"
        f"seed_prefill_dispatches~={sum(len(w['prompt']) for w in work)}")
    assert toks == toks_seed, (toks, toks_seed)
    data["paged"] = {"tok_per_s": toks / dt, "p50_ticks": p50,
                     "p99_ticks": p99,
                     "mean_page_utilization": st["mean_page_utilization"],
                     "preemptions": st["preemptions"]}

    if not dual:
        return data

    # ---- dual-branch engine: MHA||MLP branch-parallel decode dispatch ----
    work = _workload(cfg.vocab)
    import dataclasses
    dt_d, done_d, _ = _run_paged(cfg, params, work,
                                 dataclasses.replace(ecfg, dual_branch=True))
    toks_d = sum(len(r.generated) for r in done_d)
    # the CPU fallback replays the sequential path's exact ops, so tokens
    # are identical request-for-request; the fused TPU kernel's tiled FFN
    # accumulation is only tolerance-close to mlp_apply, where a near-tie
    # argmax may legitimately flip — don't hard-fail there
    from repro.kernels.ops import _default_use_pallas
    tok_map, tok_map_d = ({r.rid: r.generated for r in done},
                          {r.rid: r.generated for r in done_d})
    if not _default_use_pallas():
        assert tok_map_d == tok_map, \
            "dual-branch tokens diverged from sequential decode"
    elif tok_map_d != tok_map:
        csv("serving_dual_branch_token_drift", 0,
            f"mismatched_requests="
            f"{sum(tok_map_d[r] != tok_map[r] for r in tok_map)}")
    csv("serving_dual_branch_engine", dt_d * 1e6,
        f"tok_per_s={toks_d/dt_d:.0f};"
        f"dual_vs_sequential={dt/dt_d:.2f}")
    data["dual"] = {"tok_per_s": toks_d / dt_d,
                    "sequential_tok_per_s": toks / dt,
                    "speedup_vs_sequential": dt / dt_d}

    # structural gate: no extra collectives under explicit TP
    if len(jax.devices()) >= 2:
        counts = _dual_structural_gate()
        csv("serving_dual_branch_collectives", 0,
            f"sequential={counts['sequential']};dual={counts['dual']}")
        data["dual"]["collectives"] = counts
    else:
        csv("serving_dual_branch_collectives", 0, "SKIPPED_single_device")
    return data


def main():
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--dual", action="store_true",
                    help="also bench the dual-branch engine + structural "
                         "collectives gate")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_serving.json")
    ap.add_argument("--json-dir", default=".")
    args = ap.parse_args()

    def csv(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    data = bench(csv, dual=args.dual)
    if args.json:
        path = os.path.join(args.json_dir, "BENCH_serving.json")
        with open(path, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
