"""Serving benchmark: ragged Poisson arrivals through the paged engine vs
the seed token-by-token engine — tok/s, TTFT / inter-token / request
latency percentiles (measured BY THE ENGINE's metrics registry, not
recomputed bench-side), dispatches per tick, page utilization, preemption
count.

Every workload in the emitted JSON is LABELED — the seed engine is only a
meaningful baseline on the prefill-bound poisson load (it dispatches once
per token), so ``paged.speedup_vs_seed`` is reported under its label
rather than read as a universal speedup.  The packed engine's own
baseline is the padded reference layout, compared where it matters:

  * ``paged`` (label ``poisson``) — ONE flat (token_budget,) dispatch per
    tick serving prefill and decode lanes together as ragged segments
    (the segment-aware block-table kernel), vs the seed token-by-token
    engine on the IDENTICAL workload; ``dispatches_per_tick == 1``
    asserted, ``tokens_per_dispatch`` / ``padding_fraction`` reported
    next to tok/s.
  * ``burst`` (label ``prefill-burst``) — heavier Poisson arrivals +
    finer chunk, so most ticks carry both phases (the mixed-phase regime
    the single dispatch targets).
  * ``decode_heavy`` (label ``decode-heavy``) — short prompts, long
    generations: most ticks are all-decode, where the padded layout burns
    slots*chunk FLOPs to advance slots tokens.  The SAME workload is
    driven through a padded-reference engine (the pre-packing layout,
    defined HERE so src/repro/serve/ stays free of pad-out code) and CI
    gates packed tok/s >= padded tok/s with identical token streams.
    The label also carries the ``spec`` engine — self-speculative decode
    (``spec_tokens=4``, the FAL early-exit draft) on the same workload:
    greedy AND seeded streams asserted bit-identical to the non-spec
    packed engine, ``dispatches_per_tick == 1`` with speculation on, and
    CI gates spec tok/s >= packed tok/s on the seeded pair plus a
    recorded mean/p50 accepted length.
  * ``repeated_prefix`` (label ``repeated-prefix``) — N requests sharing
    one long page-aligned system prompt (Poisson arrivals after a cold
    donor): the SAME workload through a prefix-cached engine and a cold
    one.  Hits map the cached KV pages (refcount shares), prefill only
    their divergence suffix and seed the FAL first-attention signal from
    the cached prefix; full-prompt hits enter decode on their first tick.
    Token identity hot-vs-cold asserted; CI gates
    ``prefix_hit_rate > 0.9`` and hot-hit TTFT < cold TTFT here.
  * ``dual``  — (``--dual``) the dual-branch (MHA||MLP) engine: each
    steady-state block's FFN issued off the cached per-slot
    first-attention signal concurrently with the paged KV gather; asserts
    token identity and gates on the structural no-extra-collectives
    assertion under explicit TP.

Every engine is warmed up before timing, and every ``dispatch_path`` in
the emitted JSON comes from the RUNTIME kernel-dispatch registry
(``kernels.ops.dispatch_paths()``): the dispatchers record fused-tpu vs
cpu-fallback per call site when their programs trace, so a cold/fallback
run can never read as a kernel regression and the label can never be a
bench-side guess.

``--trace`` re-runs the burst workload with a ``repro.obs.Tracer``
attached, writes a Perfetto-loadable Chrome trace (per-tick spans,
per-dispatch spans, per-request lifecycle events) and records the tracing
overhead as a tok/s ratio — CI gates it at < 5%.

Standalone:  PYTHONPATH=src python benchmarks/bench_serving.py [--dual]
             [--trace] [--json] (writes BENCH_serving.json)
"""
from __future__ import annotations

import os

# standalone runs need the same forced host-device count benchmarks.run
# applies (the --dual structural gate lowers on a 2-device mesh); must run
# BEFORE jax import, no-op when run.py already forced >= 8
try:
    from benchmarks.hostdev import force_host_devices
except ImportError:   # plain-script invocation: benchmarks/ itself on path
    from hostdev import force_host_devices

force_host_devices()

import dataclasses
import math
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.kernels import ops
from repro.models import model as M
from repro.obs.trace import NULL_TRACER, Tracer, validate_chrome_trace
from repro.serve.decode import ContinuousBatcher, Request
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import (EngineConfig, PackedTick, PagedEngine,
                                   ServeRequest)


class PaddedTickEngine(PagedEngine):
    """Reference engine reproducing the pre-packing padded tick layout:
    every tick dispatches a flat (slots * prefill_chunk,) buffer where
    lane i occupies [i*chunk, (i+1)*chunk) and its unused tail rides as
    padding (tok_pos == -1).  Token-identical to the packed engine; pays
    the padded rectangle's FLOPs.  Lives bench-side on purpose — CI greps
    src/repro/serve/ clean of pad-out layouts."""

    def _plan_pack(self):
        S, C = self.ecfg.slots, self.ecfg.prefill_chunk
        tokens = np.zeros((S * C,), np.int32)
        tok_slot = np.repeat(np.arange(S, dtype=np.int32), C)
        tok_pos = np.full((S * C,), -1, np.int32)
        seg_last = np.full((S,), -1, np.int32)
        n_taken = np.zeros((S,), np.int32)
        live = 0
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            rem = r.known()[r.pos:r.pos + C]
            n = len(rem)
            if n == 0:
                continue
            tokens[i * C:i * C + n] = rem
            tok_pos[i * C:i * C + n] = r.pos + np.arange(n)
            seg_last[i] = i * C + n - 1
            n_taken[i] = n
            live += n
        return PackedTick(tokens, tok_slot, tok_pos, seg_last, n_taken,
                          live)


def measured_dispatch_path():
    """(per-site map, consensus label) from the RUNTIME dispatch registry.
    Call after the engines have traced their programs; 'mixed' means call
    sites disagree (e.g. a fused kernel with a per-shape fallback)."""
    paths = ops.dispatch_paths()
    vals = set(paths.values())
    if not vals:
        return paths, "unmeasured"
    return paths, vals.pop() if len(vals) == 1 else "mixed"


def _workload(vocab, n_requests=12, seed=0, rate=0.5, prompt_lo=32,
              prompt_hi=97, new_lo=8, new_hi=25):
    """Poisson arrivals (exp inter-arrival, in engine ticks), ragged
    prompts, ragged generation lengths.  The prompt/generation ranges set
    the workload's phase mix: the defaults are prefill-bound; short
    prompts + long generations make a decode-heavy load."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests)).astype(int)
    return [
        {"rid": i,
         "arrival_tick": int(arrivals[i]),
         "prompt": rng.integers(0, vocab,
                                int(rng.integers(prompt_lo, prompt_hi))),
         "max_new": int(rng.integers(new_lo, new_hi))}
        for i in range(n_requests)
    ]


def _prefix_workload(vocab, page, n_requests=16, seed=7, rate=1.0,
                     sys_pages=4, tail_lo=8, tail_hi=17, full_every=5):
    """N requests sharing one page-aligned system prompt.  Request 0 is
    the cold donor: it arrives alone and finishes before anyone else
    arrives, so every later admission can hit its parked prefix.  The
    rest arrive Poisson with unique short tails — and every
    ``full_every``-th reuses the system prompt VERBATIM, the full-prompt
    hit shape that enters decode on its first tick."""
    rng = np.random.default_rng(seed)
    sysp = rng.integers(0, vocab, sys_pages * page)
    work = [{"rid": 0, "arrival_tick": 0,
             "prompt": np.concatenate([sysp, rng.integers(0, vocab, 12)]),
             "max_new": 4}]
    # donor: 76 prefill tokens (3 chunks at chunk=32) + 4 decode ticks,
    # parked at finish — a 16-tick gap keeps every follower behind it
    arrivals = 16 + np.cumsum(
        rng.exponential(1.0 / rate, n_requests - 1)).astype(int)
    for i in range(1, n_requests):
        prompt = (sysp.copy() if i % full_every == 0 else
                  np.concatenate([sysp, rng.integers(
                      0, vocab, int(rng.integers(tail_lo, tail_hi)))]))
        work.append({"rid": i, "arrival_tick": int(arrivals[i - 1]),
                     "prompt": prompt,
                     "max_new": int(rng.integers(8, 17))})
    return sysp, work


def _drive(submit, step, pending, active_or_queued):
    """Tick loop feeding arrivals at their scheduled tick; returns
    (wall seconds, ticks driven)."""
    tick = 0
    t0 = time.time()
    while pending or active_or_queued():
        for w in list(pending):
            if w["arrival_tick"] <= tick:
                submit(w, tick)
                pending.remove(w)
        if active_or_queued():
            step()
        tick += 1
    return time.time() - t0, tick


def _warmup(engine, mk_req):
    """Compile the engine's single jitted program outside the timed region:
    the warmup request's prompt (40 tokens) exceeds the prefill chunk and
    it decodes several tokens, so the flat packed program is traced at
    every segment mix — nothing is ever timed cold."""
    engine.submit(mk_req())
    engine.run()


def _run_paged(cfg, params, work, ecfg, tracer=None, cls=PagedEngine,
               sampling=None):
    """Drive one paged-engine run over ``work``; returns (wall seconds,
    finished requests, warmup-corrected stats).  ``sampling`` maps a
    workload entry to its SamplingParams (default: greedy)."""
    eng = cls(cfg, params, ecfg, tracer=tracer)
    _warmup(eng, lambda: ServeRequest(rid=-1, prompt=np.arange(40) % cfg.vocab,
                                      max_new=4))
    # drop the warmup request from every reported stat (jit stays warm;
    # reset also drops the warmup's trace events so the exported trace
    # holds exactly the timed workload)
    eng.finished.clear()
    eng.reset_stats()

    def submit(w, tick):
        eng.submit(ServeRequest(
            rid=w["rid"], prompt=w["prompt"], max_new=w["max_new"],
            sampling=sampling(w) if sampling else SamplingParams()))

    dt, _ = _drive(
        submit, eng.step, list(work),
        lambda: eng.queue or any(s is not None for s in eng.slots))
    return dt, eng.finished, eng.stats()


def _run_prefix(cfg, params, work, ecfg):
    """Drive ``work`` through a fresh engine, warming up with TWO
    identical page-aligned prompts run back-to-back: the first traces the
    packed program, the second (a full-prompt hit when the prefix cache
    is on) traces the decode-entry tick AND the copy-on-write page-copy
    program — nothing in the timed region compiles cold.  Tree + stats
    are reset after warmup so the timed hit rate starts from an empty
    radix tree."""
    eng = PagedEngine(cfg, params, ecfg)
    wp = np.arange(48) % cfg.vocab          # 3 pages at page_size 16
    for rid in (-1, -2):
        eng.submit(ServeRequest(rid=rid, prompt=wp.copy(), max_new=4))
        eng.run()
    eng.finished.clear()
    if eng.pcache is not None:
        eng.pcache.clear()
    eng.reset_stats()

    def submit(w, tick):
        eng.submit(ServeRequest(rid=w["rid"], prompt=w["prompt"],
                                max_new=w["max_new"]))

    dt, _ = _drive(
        submit, eng.step, list(work),
        lambda: eng.queue or any(s is not None for s in eng.slots))
    return dt, eng.finished, eng.stats()


def _late_block_damped(params, draft_blocks, scale=0.02):
    """Emulate the trained-FAL regime for the timed speculative run.

    Random-init weights make the early-exit draft meaningless: every late
    block REWRITES the residual stream with noise, so draft and full-depth
    logits disagree and exact-match acceptance collapses — the opposite of
    a trained FAL model, where every later MLP already reads block 0's
    first-attention signal and late blocks refine rather than overturn
    (the paper's premise, and the regime speculation targets).  Damping
    the residual-writing projections (attn.wo / ffn.wo) of the blocks the
    draft skips makes the shallow prefix agree with the full model, so
    the bench times the ENGINE at a trained-model-like acceptance rate.
    Correctness never leans on this: spec-vs-packed token identity is
    asserted on the raw random weights (greedy) AND on these (seeded).

    The draft runs block 0 plus the first ``draft_blocks - 1`` entries of
    the stacked ``blocks_dense``, so stacked indices >= draft_blocks - 1
    are the skipped ones."""
    keep = draft_blocks - 1

    def damp(path, a):
        names = [getattr(k, "key", None) for k in path]
        if names[-1] != "wo":
            return a
        s = np.where(np.arange(a.shape[0]) >= keep, scale, 1.0)
        return a * s.reshape((-1,) + (1,) * (a.ndim - 1)).astype(np.float32)

    out = dict(params)
    out["blocks_dense"] = jax.tree_util.tree_map_with_path(
        damp, params["blocks_dense"])
    return out


def _dual_structural_gate():
    """Shared gate (core.tp.assert_dual_no_extra_collectives) on a 2-device
    mesh: dual-branch decode ticks must lower to the SAME collective counts
    as sequential ones (ONE fused all-reduce).  Returns the fal counts."""
    from repro.core import tp
    mesh = jax.make_mesh((2,), ("model",))
    return tp.assert_dual_no_extra_collectives(mesh, modes=("fal",))["fal"]


def bench(csv, dual=False, trace=False, trace_out="TRACE_serving.json"):
    cfg = get_config("gpt2-117m").replace(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, d_ff=1024,
        vocab=2048, max_seq=512, dtype="float32", param_dtype="float32",
        remat=False, attn_block_q=64, attn_block_k=128, connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    max_seq, slots = 160, 4
    data = {}

    # ---- seed engine: contiguous cache, one token per tick ---------------
    work = _workload(cfg.vocab)
    seed_eng = ContinuousBatcher(cfg, params, batch_slots=slots,
                                 max_seq=max_seq)
    _warmup(seed_eng, lambda: Request(rid=-1,
                                      prompt=np.arange(40) % cfg.vocab,
                                      max_new=4))
    seed_eng.reset_stats()
    seed_done = []

    def submit_seed(w, tick):
        seed_eng.submit(Request(rid=w["rid"], prompt=w["prompt"],
                                max_new=w["max_new"]))

    dt_seed, _ = _drive(
        submit_seed, lambda: seed_done.extend(seed_eng.step()), list(work),
        lambda: seed_eng.queue or any(s is not None for s in seed_eng.slots))
    toks_seed = sum(len(r.generated) for r in seed_done)
    csv("serving_seed_engine", dt_seed * 1e6,
        f"tok_per_s={toks_seed/dt_seed:.0f};requests={len(work)}")
    data["seed"] = {"tok_per_s": toks_seed / dt_seed,
                    "requests": len(work),
                    "dispatches_per_tick":
                        seed_eng.stats()["dispatches_per_tick"]}

    # ---- paged engine (packed ticks): ONE flat dispatch per tick ---------
    work = _workload(cfg.vocab)
    ecfg = EngineConfig(page_size=16, num_pages=48, slots=slots,
                        prefill_chunk=32, max_seq=max_seq)
    dt, done, st = _run_paged(cfg, params, work, ecfg)
    toks = sum(len(r.generated) for r in done)
    assert toks == toks_seed, (toks, toks_seed)
    assert st["dispatches_per_tick"] == 1.0, st
    # the dispatch path the engines ACTUALLY lowered, from the runtime
    # kernel-dispatch registry (recorded at trace time in kernels.ops)
    site_paths, path = measured_dispatch_path()
    data["dispatch_path"] = path
    data["dispatch_paths"] = site_paths
    csv("serving_paged_engine", dt * 1e6,
        f"tok_per_s={toks/dt:.0f};"
        f"tokens_per_dispatch={st['tokens_per_dispatch']['mean']:.1f};"
        f"padding_fraction={st['padding_fraction']['mean']:.2f};"
        f"ttft_p50_ms={st['ttft_ms']['p50']:.1f};"
        f"ttft_p99_ms={st['ttft_ms']['p99']:.1f};"
        f"itl_p50_ms={st['inter_token_ms']['p50']:.1f};"
        f"dispatches_per_tick={st['dispatches_per_tick']:.2f};"
        f"path={path}")
    csv("serving_paged_pages", 0,
        f"mean_util={st['mean_page_utilization']:.2f};"
        f"peak={st['pages']['peak_in_use']};"
        f"page_bytes={st['pages']['page_bytes']};"
        f"peak_bytes={st['pages']['peak_bytes_in_use']};"
        f"preemptions={st['preemptions']}")
    # the seed engine dispatches ONCE PER TOKEN, so this ratio is only
    # meaningful on the prefill-bound poisson label — not a universal
    # packed-engine speedup (that gate lives in decode_heavy below)
    csv("serving_prefill_speedup", 0,
        f"paged_vs_seed={dt_seed/dt:.2f};workload=poisson;"
        f"seed_prefill_dispatches~={sum(len(w['prompt']) for w in work)}")
    data["paged"] = {"workload_label": "poisson",
                     "tok_per_s": toks / dt,
                     "speedup_vs_seed": dt_seed / dt,
                     "token_budget": st["token_budget"],
                     "tokens_per_dispatch": st["tokens_per_dispatch"],
                     "padding_fraction": st["padding_fraction"],
                     "ttft_p50_ms": st["ttft_ms"]["p50"],
                     "ttft_p99_ms": st["ttft_ms"]["p99"],
                     "inter_token_p50_ms": st["inter_token_ms"]["p50"],
                     "inter_token_p99_ms": st["inter_token_ms"]["p99"],
                     "queue_wait_p50_ticks": st["queue_wait_ticks"]["p50"],
                     "p50_ticks": st["request_latency_ticks"]["p50"],
                     "p99_ticks": st["request_latency_ticks"]["p99"],
                     "decode_p50_ms": st["dispatch_ms"]["p50"],
                     "decode_p99_ms": st["dispatch_ms"]["p99"],
                     "dispatches_per_tick": st["dispatches_per_tick"],
                     "mean_occupancy": st["mean_occupancy"],
                     "mean_page_utilization": st["mean_page_utilization"],
                     "page_bytes": st["pages"]["page_bytes"],
                     "peak_bytes_in_use": st["pages"]["peak_bytes_in_use"],
                     "preemptions": st["preemptions"],
                     "dispatch_path": path}
    tok_map = {r.rid: r.generated for r in done}

    # ---- prefill-burst load: the mixed-phase regime ----------------------
    # heavier arrivals + a finer chunk keep both phases live in most ticks;
    # decode lanes ride the same dispatch instead of queueing behind a
    # prefill program
    burst = dict(n_requests=16, rate=2.0)
    ecfg_burst = dataclasses.replace(ecfg, prefill_chunk=8)
    dt_m, done_m, st_m = _run_paged(
        cfg, params, _workload(cfg.vocab, **burst), ecfg_burst)
    toks_m = sum(len(r.generated) for r in done_m)
    assert st_m["dispatches_per_tick"] == 1.0, st_m
    csv("serving_packed_tick_burst", dt_m * 1e6,
        f"tok_per_s={toks_m/dt_m:.0f};"
        f"tokens_per_dispatch={st_m['tokens_per_dispatch']['mean']:.1f};"
        f"padding_fraction={st_m['padding_fraction']['mean']:.2f};"
        f"ttft_p50_ms={st_m['ttft_ms']['p50']:.1f};"
        f"itl_p50_ms={st_m['inter_token_ms']['p50']:.1f};"
        f"decode_p50_ms={st_m['dispatch_ms']['p50']:.1f};"
        f"dispatches_per_tick={st_m['dispatches_per_tick']:.2f};"
        f"occupancy={st_m['mean_occupancy']:.2f};"
        f"path={path}")
    data["burst"] = {"workload_label": "prefill-burst",
                     "tok_per_s": toks_m / dt_m,
                     "token_budget": st_m["token_budget"],
                     "tokens_per_dispatch": st_m["tokens_per_dispatch"],
                     "padding_fraction": st_m["padding_fraction"],
                     "ttft_p50_ms": st_m["ttft_ms"]["p50"],
                     "ttft_p99_ms": st_m["ttft_ms"]["p99"],
                     "inter_token_p50_ms": st_m["inter_token_ms"]["p50"],
                     "inter_token_p99_ms": st_m["inter_token_ms"]["p99"],
                     "decode_p50_ms": st_m["dispatch_ms"]["p50"],
                     "decode_p99_ms": st_m["dispatch_ms"]["p99"],
                     "dispatches_per_tick": st_m["dispatches_per_tick"],
                     "mean_occupancy": st_m["mean_occupancy"],
                     "preemptions": st_m["preemptions"],
                     "dispatch_path": path,
                     "workload": {**burst,
                                  "prefill_chunk": ecfg_burst.prefill_chunk}}
    burst_tokens = {r.rid: r.generated for r in done_m}

    # ---- decode-heavy load: packed vs the padded reference layout --------
    # short prompts, long generations: most ticks are all-decode, where the
    # padded layout burns slots*chunk FLOPs to advance slots tokens.  The
    # SAME workload through both layouts — identical tokens required, and
    # CI gates packed tok/s >= padded tok/s here (the regime the flat
    # token budget targets)
    decode_kw = dict(n_requests=12, rate=2.0, seed=3, prompt_lo=8,
                     prompt_hi=17, new_lo=32, new_hi=49)
    ecfg_dec = dataclasses.replace(ecfg, prefill_chunk=8)
    dt_p, done_p, st_p = _run_paged(
        cfg, params, _workload(cfg.vocab, **decode_kw), ecfg_dec)
    dt_b, done_b, st_b = _run_paged(
        cfg, params, _workload(cfg.vocab, **decode_kw), ecfg_dec,
        cls=PaddedTickEngine)
    assert ({r.rid: r.generated for r in done_p}
            == {r.rid: r.generated for r in done_b}), \
        "packed tokens diverged from the padded reference layout"
    assert st_p["dispatches_per_tick"] == 1.0, st_p
    toks_p = sum(len(r.generated) for r in done_p)
    toks_b = sum(len(r.generated) for r in done_b)
    csv("serving_packed_vs_padded_decode_heavy", dt_p * 1e6,
        f"packed_tok_per_s={toks_p/dt_p:.0f};"
        f"padded_tok_per_s={toks_b/dt_b:.0f};"
        f"speedup_packed_vs_padded={dt_b/dt_p:.2f};"
        f"packed_tokens_per_dispatch="
        f"{st_p['tokens_per_dispatch']['mean']:.1f};"
        f"packed_padding_fraction={st_p['padding_fraction']['mean']:.2f};"
        f"padded_padding_fraction={st_b['padding_fraction']['mean']:.2f};"
        f"path={path}")
    data["decode_heavy"] = {
        "workload_label": "decode-heavy",
        "packed_tok_per_s": toks_p / dt_p,
        "padded_tok_per_s": toks_b / dt_b,
        "speedup_packed_vs_padded": dt_b / dt_p,
        "token_budget": st_p["token_budget"],
        "padded_budget": ecfg_dec.slots * ecfg_dec.prefill_chunk,
        "tokens_per_dispatch": st_p["tokens_per_dispatch"],
        "padding_fraction": st_p["padding_fraction"],
        "padded_padding_fraction": st_b["padding_fraction"],
        "dispatches_per_tick": st_p["dispatches_per_tick"],
        "workload": decode_kw,
    }

    # ---- quantized KV pages (kv_dtype=int8) vs bf16 storage --------------
    # int8 pages store K/V at 1 byte/elt plus one fp32 per-page-row scale
    # shared across KV heads (dequantized inside the paged kernels' VMEM
    # load, fp32 softmax accumulators); bf16 is the 2-byte reference
    # storage.  The rounding is a bounded logit perturbation (~0.4% of
    # max|logit|, tests/test_quantized_kv.py) — below every argmax gap on
    # short streams, but a greedy stream FORKS at its first near-tie flip,
    # and random-init logits hit one roughly every hundred tokens.  So
    # token identity is gated where it is a real property — a
    # bounded-length workload on which int8, bf16 and the default engine
    # must agree bit-for-bit — while the long labels above (poisson,
    # prefill-burst, decode-heavy) gate measured greedy FIDELITY vs the
    # default engine: identical-request fraction and common-prefix token
    # fraction, CI-floored.  The capacity gate is CONCURRENT REQUESTS PER
    # HBM BYTE on the full decode-heavy load: at equal num_pages the pool
    # shrinks by page_bytes_bf16/page_bytes_int8, so the same occupancy
    # rides on ~half the HBM — CI gates the measured ratio >= 1.8x.
    ecfg_q = dataclasses.replace(ecfg_dec, kv_dtype="int8")
    dt_qb, done_qb, st_qb = _run_paged(
        cfg, params, _workload(cfg.vocab, **decode_kw),
        dataclasses.replace(ecfg_dec, kv_dtype="bf16"))
    dt_q, done_q, st_q = _run_paged(
        cfg, params, _workload(cfg.vocab, **decode_kw), ecfg_q)

    def _fidelity(ref, out):
        """Greedy fidelity of ``out`` vs ``ref``: requests matching
        bit-for-bit, and the fraction of reference tokens inside the
        per-request common prefix (a stream forks at its first flip)."""
        ident = sum(1 for r in ref if tuple(out[r]) == tuple(ref[r]))
        agree = total = 0
        for r in ref:
            n = 0
            for x, y in zip(ref[r], out[r]):
                if x != y:
                    break
                n += 1
            agree += n
            total += len(ref[r])
        return {"identical_requests": ident, "requests": len(ref),
                "common_prefix_frac": agree / max(total, 1)}

    _, done_q2, _ = _run_paged(
        cfg, params, _workload(cfg.vocab),
        dataclasses.replace(ecfg, kv_dtype="int8"))
    _, done_q3, _ = _run_paged(
        cfg, params, _workload(cfg.vocab, **burst),
        dataclasses.replace(ecfg_burst, kv_dtype="int8"))
    fidelity = {
        "decode-heavy": _fidelity({r.rid: r.generated for r in done_p},
                                  {r.rid: r.generated for r in done_q}),
        "poisson": _fidelity(tok_map,
                             {r.rid: r.generated for r in done_q2}),
        "prefill-burst": _fidelity(burst_tokens,
                                   {r.rid: r.generated for r in done_q3}),
    }
    for label, f in fidelity.items():
        assert f["common_prefix_frac"] >= 0.7, (label, f)
        assert 2 * f["identical_requests"] >= f["requests"], (label, f)

    # exact-identity gate: bounded streams, all three storages bit-equal
    ident_kw = dict(n_requests=8, rate=2.0, seed=4, prompt_lo=8,
                    prompt_hi=17, new_lo=4, new_hi=9)
    _, di0, _ = _run_paged(
        cfg, params, _workload(cfg.vocab, **ident_kw), ecfg_dec)
    _, dib, _ = _run_paged(
        cfg, params, _workload(cfg.vocab, **ident_kw),
        dataclasses.replace(ecfg_dec, kv_dtype="bf16"))
    _, diq, _ = _run_paged(
        cfg, params, _workload(cfg.vocab, **ident_kw), ecfg_q)
    ti0 = {r.rid: tuple(r.generated) for r in di0}
    tib = {r.rid: tuple(r.generated) for r in dib}
    tiq = {r.rid: tuple(r.generated) for r in diq}
    assert tiq == tib == ti0, \
        "int8/bf16 KV greedy tokens diverged from the default engine on " \
        "the bounded identity workload"
    assert st_q["dispatches_per_tick"] == 1.0, st_q
    pb_q = st_q["pages"]["page_bytes"]
    pb_b16 = st_qb["pages"]["page_bytes"]
    # concurrent requests per HBM byte: occupancy over the pool's total
    # bytes, both MEASURED (occupancy from the engine's per-tick stats,
    # page_bytes summed over the actual device pools incl. scale pools)
    rphb_q = st_q["mean_occupancy"] / (ecfg_q.num_pages * pb_q)
    rphb_b16 = st_qb["mean_occupancy"] / (ecfg_q.num_pages * pb_b16)
    cap_ratio = rphb_q / rphb_b16
    assert cap_ratio >= 1.8, (
        f"int8 KV: concurrent requests per HBM byte only {cap_ratio:.2f}x "
        f"of bf16 (need >= 1.8x): page_bytes int8={pb_q} bf16={pb_b16}")
    site_paths, _ = measured_dispatch_path()
    assert "paged_packed_attention.int8" in site_paths, site_paths
    toks_q = sum(len(r.generated) for r in done_q)
    toks_qb = sum(len(r.generated) for r in done_qb)
    csv("serving_quantized_kv_decode_heavy", dt_q * 1e6,
        f"int8_tok_per_s={toks_q/dt_q:.0f};"
        f"bf16_tok_per_s={toks_qb/dt_qb:.0f};"
        f"page_bytes_int8={pb_q};page_bytes_bf16={pb_b16};"
        f"req_per_hbm_byte_ratio={cap_ratio:.2f};"
        f"greedy_identical_bounded=1;"
        f"common_prefix_frac="
        f"{fidelity['decode-heavy']['common_prefix_frac']:.2f};"
        f"dispatches_per_tick={st_q['dispatches_per_tick']:.2f};"
        f"path={site_paths['paged_packed_attention.int8']}")
    data["quantized"] = {
        "workload_label": "decode-heavy",
        "kv_dtype": "int8",
        "int8_tok_per_s": toks_q / dt_q,
        "bf16_tok_per_s": toks_qb / dt_qb,
        "page_bytes": {"int8": pb_q, "bf16": pb_b16,
                       "default": st_p["pages"]["page_bytes"]},
        "pool_bytes": {"int8": ecfg_q.num_pages * pb_q,
                       "bf16": ecfg_q.num_pages * pb_b16},
        "requests_per_hbm_byte": {"int8": rphb_q, "bf16": rphb_b16},
        "requests_per_hbm_byte_ratio_int8_vs_bf16": cap_ratio,
        # bit-exact three-way identity (int8 == bf16 == default) holds on
        # the bounded workload; the long labels record measured fidelity
        # (greedy streams fork at near-tie argmax flips, ~1/100 tokens
        # on random-init logits)
        "greedy_identical": True,
        "identity_workload": ident_kw,
        "greedy_fidelity": fidelity,
        "dispatches_per_tick": st_q["dispatches_per_tick"],
        "dispatch_path": site_paths["paged_packed_attention.int8"],
    }

    # ---- self-speculative decode on the same decode-heavy load -----------
    # the FAL early-exit draft (first draft_blocks blocks + LM head)
    # proposes spec_tokens-1 tokens per decode lane INSIDE the one jitted
    # tick; the full-depth packed forward verifies each proposal as a
    # single length-n segment.  Exact-match acceptance is lossless, so the
    # spec streams are asserted bit-identical to the non-spec packed
    # engine's — greedy on the raw random-init weights (where the draft
    # disagrees with the full model almost always: the adversarial case
    # for the accept/rollback machinery), and seeded on the
    # trained-regime weights below.  The timed tok/s comparison runs
    # seeded (fold_in(seed, position) keys shared between draft and
    # verify); CI gates spec tok/s >= packed tok/s on that pair — same
    # sampler and same weights both sides — plus dispatches_per_tick ==
    # 1.0 with speculation on and a recorded accepted-length p50 >= 2.
    ecfg_spec = dataclasses.replace(ecfg_dec, spec_tokens=4)

    dt_sg, done_sg, st_sg = _run_paged(
        cfg, params, _workload(cfg.vocab, **decode_kw), ecfg_spec)
    assert ({r.rid: r.generated for r in done_sg}
            == {r.rid: r.generated for r in done_p}), \
        "greedy spec tokens diverged from the non-spec packed engine"
    assert st_sg["dispatches_per_tick"] == 1.0, st_sg

    def seeded(w):
        return SamplingParams(temperature=0.9, top_k=50, top_p=0.95,
                              seed=int(w["rid"]))

    params_tr = _late_block_damped(params, ecfg_spec.draft_blocks)
    dt_ps, done_ps, st_ps = _run_paged(
        cfg, params_tr, _workload(cfg.vocab, **decode_kw), ecfg_dec,
        sampling=seeded)
    dt_s, done_s, st_s = _run_paged(
        cfg, params_tr, _workload(cfg.vocab, **decode_kw), ecfg_spec,
        sampling=seeded)
    assert ({r.rid: r.generated for r in done_s}
            == {r.rid: r.generated for r in done_ps}), \
        "seeded spec tokens diverged from the non-spec packed engine"
    assert st_s["dispatches_per_tick"] == 1.0, st_s
    toks_s = sum(len(r.generated) for r in done_s)
    toks_ps = sum(len(r.generated) for r in done_ps)
    sp = st_s["spec"]
    csv("serving_spec_decode_heavy", dt_s * 1e6,
        f"spec_tok_per_s={toks_s/dt_s:.0f};"
        f"packed_tok_per_s={toks_ps/dt_ps:.0f};"
        f"speedup_spec_vs_packed={dt_ps/dt_s:.2f};"
        f"spec_tokens={sp['spec_tokens']};draft_blocks={sp['draft_blocks']};"
        f"acceptance_rate={sp['acceptance_rate']:.3f};"
        f"accepted_len_mean={sp['accepted_len']['mean']:.2f};"
        f"accepted_len_p50={sp['accepted_len']['p50']:.1f};"
        f"raw_init_acceptance_rate="
        f"{st_sg['spec']['acceptance_rate']:.3f};"
        f"dispatches_per_tick={st_s['dispatches_per_tick']:.2f};"
        f"path={path}")
    data["decode_heavy"]["spec"] = {
        "spec_tokens": sp["spec_tokens"],
        "draft_blocks": sp["draft_blocks"],
        "spec_tok_per_s": toks_s / dt_s,
        "seeded_packed_tok_per_s": toks_ps / dt_ps,
        "speedup_spec_vs_packed": dt_ps / dt_s,
        "token_budget": st_s["token_budget"],
        "dispatches_per_tick": st_s["dispatches_per_tick"],
        "acceptance_rate": sp["acceptance_rate"],
        "accepted_len": sp["accepted_len"],
        "greedy": {"dispatches_per_tick": st_sg["dispatches_per_tick"],
                   "weights": "raw-random-init",
                   "acceptance_rate": st_sg["spec"]["acceptance_rate"],
                   "accepted_len": st_sg["spec"]["accepted_len"]},
    }

    # ---- repeated-prefix load: radix prefix cache + COW page sharing -----
    # N requests sharing one page-aligned system prompt, Poisson arrivals
    # behind a cold donor; the SAME workload through a prefix-cached
    # engine and a cold reference.  Hits adopt the cached KV pages and
    # prefill only their divergence suffix (full-prompt hits enter decode
    # on tick one with the a1_sig seeded from the cached prefix), so the
    # hot engine's prefill-token count collapses to roughly the tails.
    sysp, work_pref = _prefix_workload(cfg.vocab, ecfg.page_size)
    dt_h, done_h, st_h = _run_prefix(
        cfg, params, work_pref,
        dataclasses.replace(ecfg, prefix_cache=True))
    dt_c, done_c, st_c = _run_prefix(cfg, params, work_pref, ecfg)
    assert ({r.rid: r.generated for r in done_h}
            == {r.rid: r.generated for r in done_c}), \
        "prefix-cache hits changed the token stream"
    pf = st_h["prefix"]
    toks_h = sum(len(r.generated) for r in done_h)
    toks_c = sum(len(r.generated) for r in done_c)
    csv("serving_repeated_prefix", dt_h * 1e6,
        f"tok_per_s_hot={toks_h/dt_h:.0f};"
        f"tok_per_s_cold={toks_c/dt_c:.0f};"
        f"prefix_hit_rate={pf['hit_rate']:.3f};"
        f"prefill_tokens_hot={st_h['prefill_tokens']};"
        f"prefill_tokens_cold={st_c['prefill_tokens']};"
        f"ttft_hit_p50_ticks={pf['ttft_hit_ticks']['p50']:.0f};"
        f"ttft_cold_ref_p50_ticks={st_c['ttft_ticks']['p50']:.0f};"
        f"cow_copies={pf['cow_copies']};"
        f"a1_sig_seeded={pf['a1_sig_seeded']};"
        f"path={path}")
    data["repeated_prefix"] = {
        "workload_label": "repeated-prefix",
        "requests": len(work_pref),
        "system_prompt_tokens": len(sysp),
        "prefix_hit_rate": pf["hit_rate"],
        "hits": pf["hits"],
        "misses": pf["misses"],
        "hit_tokens_p50": pf["hit_tokens"]["p50"],
        "cow_copies": pf["cow_copies"],
        "a1_sig_seeded": pf["a1_sig_seeded"],
        "inserted_pages": pf["inserted_pages"],
        "evicted_pages": pf["evicted_pages"],
        "cached_pages_end": pf["cached_pages"],
        "prefill_tokens_saved":
            st_c["prefill_tokens"] - st_h["prefill_tokens"],
        "hot": {"tok_per_s": toks_h / dt_h,
                "prefill_tokens": st_h["prefill_tokens"],
                "prefill_tok_per_s": st_h["prefill_tokens"] / dt_h,
                "ttft_p50_ticks": st_h["ttft_ticks"]["p50"],
                "ttft_hit_p50_ticks": pf["ttft_hit_ticks"]["p50"],
                "ttft_hit_p50_ms": pf["ttft_hit_ms"]["p50"],
                "ttft_cold_p50_ticks": pf["ttft_cold_ticks"]["p50"],
                "preemptions": st_h["preemptions"]},
        "cold": {"tok_per_s": toks_c / dt_c,
                 "prefill_tokens": st_c["prefill_tokens"],
                 "prefill_tok_per_s": st_c["prefill_tokens"] / dt_c,
                 "ttft_p50_ticks": st_c["ttft_ticks"]["p50"],
                 "ttft_p50_ms": st_c["ttft_ms"]["p50"],
                 "preemptions": st_c["preemptions"]},
        "dispatch_path": path,
    }

    # ---- tracing overhead: identical burst workload, tracer attached -----
    # ONE engine (one compiled program), interleaved traced/untraced passes
    # with best-of-N per mode, so host timing drift can't masquerade as
    # tracer cost — the gate is the marginal price of the span sites
    if trace:
        tracer = Tracer(enabled=True)
        eng = PagedEngine(cfg, params, ecfg_burst)
        _warmup(eng, lambda: ServeRequest(rid=-1,
                                          prompt=np.arange(40) % cfg.vocab,
                                          max_new=4))

        def one_pass(tr):
            eng.tracer = tr
            eng.finished.clear()
            work_tr = _workload(cfg.vocab, **burst)
            dt, _ = _drive(
                lambda w, tick: eng.submit(
                    ServeRequest(rid=w["rid"], prompt=w["prompt"],
                                 max_new=w["max_new"])),
                eng.step, list(work_tr),
                lambda: eng.queue or any(s is not None for s in eng.slots))
            toks = sum(len(r.generated) for r in eng.finished)
            assert ({r.rid: r.generated for r in eng.finished}
                    == burst_tokens), "tracing changed the token stream"
            return dt, toks

        best = {"off": math.inf, "on": math.inf}
        toks_tr = 0
        for _ in range(2):
            dt_off, _ = one_pass(NULL_TRACER)
            best["off"] = min(best["off"], dt_off)
            tracer.clear()           # export holds exactly the last pass
            dt_on, toks_tr = one_pass(tracer)
            best["on"] = min(best["on"], dt_on)
        eng.tracer = NULL_TRACER
        n_events = validate_chrome_trace(tracer.export())
        tracer.write(trace_out)
        overhead = best["on"] / best["off"]
        csv("serving_trace_overhead", best["on"] * 1e6,
            f"tok_per_s_traced={toks_tr/best['on']:.0f};"
            f"overhead_ratio={overhead:.3f};events={n_events};"
            f"trace={trace_out}")
        data["trace"] = {"tok_per_s_traced": toks_tr / best["on"],
                         "tok_per_s_untraced": toks_tr / best["off"],
                         "overhead_ratio": overhead,
                         "events": n_events,
                         "file": trace_out}

    if not dual:
        return data

    # ---- dual-branch engine: MHA||MLP branch-parallel decode -------------
    work = _workload(cfg.vocab)
    dt_d, done_d, st_d = _run_paged(cfg, params, work,
                                    dataclasses.replace(ecfg,
                                                        dual_branch=True))
    toks_d = sum(len(r.generated) for r in done_d)
    site_paths, path = measured_dispatch_path()
    data["dispatch_paths"] = site_paths
    # the CPU fallback replays the sequential path's exact ops, so tokens
    # are identical request-for-request; the fused TPU kernel's tiled FFN
    # accumulation is only tolerance-close to mlp_apply, where a near-tie
    # argmax may legitimately flip — don't hard-fail there
    tok_map_d = {r.rid: r.generated for r in done_d}
    if path == "cpu-fallback":
        assert tok_map_d == tok_map, \
            "dual-branch tokens diverged from sequential decode"
    elif tok_map_d != tok_map:
        csv("serving_dual_branch_token_drift", 0,
            f"mismatched_requests="
            f"{sum(tok_map_d[r] != tok_map[r] for r in tok_map)}")
    csv("serving_dual_branch_engine", dt_d * 1e6,
        f"tok_per_s={toks_d/dt_d:.0f};"
        f"dual_vs_sequential={dt/dt_d:.2f};"
        f"path={path}")
    data["dual"] = {"tok_per_s": toks_d / dt_d,
                    "sequential_tok_per_s": toks / dt,
                    "speedup_vs_sequential": dt / dt_d,
                    "dispatches_per_tick": st_d["dispatches_per_tick"],
                    "dispatch_path": path}

    # structural gate: no extra collectives under explicit TP
    if len(jax.devices()) >= 2:
        counts = _dual_structural_gate()
        csv("serving_dual_branch_collectives", 0,
            f"sequential={counts['sequential']};dual={counts['dual']}")
        data["dual"]["collectives"] = counts
    else:
        csv("serving_dual_branch_collectives", 0, "SKIPPED_single_device")
    return data


def main():
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--dual", action="store_true",
                    help="also bench the dual-branch engine + structural "
                         "collectives gate")
    ap.add_argument("--trace", action="store_true",
                    help="re-run the burst workload with the span tracer "
                         "attached, write a Chrome trace and record the "
                         "tok/s overhead")
    ap.add_argument("--trace-out", default="TRACE_serving.json")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_serving.json")
    ap.add_argument("--json-dir", default=".")
    args = ap.parse_args()

    def csv(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    data = bench(csv, dual=args.dual, trace=args.trace,
                 trace_out=args.trace_out)
    if args.json:
        from repro.obs.runmeta import run_metadata
        data["meta"] = run_metadata(timestamp=time.time(),
                                    dispatch_paths=ops.dispatch_paths())
        path = os.path.join(args.json_dir, "BENCH_serving.json")
        with open(path, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
