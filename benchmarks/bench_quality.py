"""Paper Table 1 / Fig 9 / Apdx D.1: model quality per connection mode at
small scale on the synthetic Markov corpus (loss ordering is the claim under
test: fal <= preln < parallel;  falplus <= fal;  ablation1 > preln;
ablation2 between parallel and fal), plus the Fig 7 quality comparison of
lossy gradient compression."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticMarkov
from repro.optim import adamw, grad_compress, schedules
from repro.train import step as tstep, trainer


def _cfg(depth=8):
    return get_config("gpt2-117m").replace(
        n_layers=depth, d_model=192, n_heads=6, n_kv_heads=6, d_ff=768,
        vocab=1024, max_seq=128, dtype="float32", param_dtype="float32",
        remat=False, attn_block_q=64, attn_block_k=128)


def bench(csv, steps=100, depth=6):
    data = SyntheticMarkov(1024, 128, 8, seed=11)
    for mode in ("preln", "parallel", "fal", "falplus",
                 "ablation1", "ablation2"):
        cfg = _cfg(depth).replace(connection=mode)
        t0 = time.time()
        _, hist = trainer.train(cfg, steps=steps, batch=8, seq_len=128,
                                data=data, log_every=0, lr=1e-3,
                                schedule="onecycle")
        # avg of last 3 logged losses for stability
        final = hist[-1]["loss"] if hist else float("nan")
        csv(f"quality_tbl1_{mode}_d{depth}",
            (time.time() - t0) / steps * 1e6,
            f"final_loss={final:.4f};ppl={jnp.exp(final):.2f}")


def bench_compress(csv, steps=80):
    """Fig 7: Grad-Q / Grad-LR degrade quality; FAL does not (lossless)."""
    data = SyntheticMarkov(1024, 128, 8, seed=13)
    cfg0 = _cfg(6)

    for name, transform, mode in (
            ("baseline", None, "preln"),
            ("grad_q", grad_compress.quantize_int8, "preln"),
            ("grad_lr", lambda g: grad_compress.lowrank(g, 4), "preln"),
            ("fal", None, "fal")):
        cfg = cfg0.replace(connection=mode)
        ocfg = adamw.AdamWConfig(lr=schedules.one_cycle(1e-3, steps))
        state = tstep.init_state(jax.random.PRNGKey(0), cfg, ocfg)
        loss_fn = tstep.make_loss_fn(cfg)
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        @jax.jit
        def raw_step(state, batch):
            (l, _), g = grad_fn(state["params"], batch)
            return l, g

        @jax.jit
        def apply(state, g):
            p, o, gn = adamw.adamw_update(state["params"], g, state["opt"],
                                          ocfg)
            return {"params": p, "opt": o}

        t0 = time.time()
        l = None
        for i in range(steps):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            l, g = raw_step(state, b)
            if transform is not None:
                g = transform(g)   # models the lossy communication payload
            state = apply(state, g)
        csv(f"quality_fig7_{name}", (time.time() - t0) / steps * 1e6,
            f"final_loss={float(l):.4f}")


def bench_depth_scaling(csv, steps=80):
    """Fig 9: FAL/FAL+ advantage grows with depth."""
    for depth in (4, 8):
        data = SyntheticMarkov(1024, 128, 8, seed=17)
        for mode in ("preln", "fal", "falplus"):
            cfg = _cfg(depth).replace(connection=mode)
            _, hist = trainer.train(cfg, steps=steps, batch=8, seq_len=128,
                                    data=data, log_every=0, lr=1e-3,
                                    schedule="onecycle")
            csv(f"quality_fig9_{mode}_L{depth}", 0,
                f"final_loss={hist[-1]['loss']:.4f}")
