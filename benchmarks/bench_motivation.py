"""Paper §3 (Fig 3 / Fig 4): CKA similarity across blocks, gradient magnitude
of MHA outputs, and per-layer attention-ablation perplexity — measured on a
briefly-trained small Pre-LN model (the paper used pretrained GPT-2)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import analysis
from repro.data.pipeline import SyntheticMarkov
from repro.train import trainer


def bench(csv, steps=150):
    cfg = get_config("gpt2-117m").replace(
        n_layers=6, d_model=192, n_heads=6, n_kv_heads=6, d_ff=768,
        vocab=1024, max_seq=128, dtype="float32", param_dtype="float32",
        remat=False, connection="preln", attn_block_q=64, attn_block_k=128)
    data = SyntheticMarkov(cfg.vocab, 128, 16, seed=23)
    t0 = time.time()
    state, _ = trainer.train(cfg, steps=steps, batch=16, seq_len=128,
                             data=data, log_every=0, lr=1e-3)
    params = state["params"]
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(10 ** 6).items()}

    # Fig 3(a): CKA of consecutive blocks
    cka = analysis.cka_table(params, cfg, batch)
    csv("motivation_fig3a_cka_mlp_in", 0,
        "avg=%.3f" % (sum(cka["mlp_in"]) / len(cka["mlp_in"])))
    csv("motivation_fig3a_cka_mha_out", 0,
        "avg=%.3f" % (sum(cka["mha_out"]) / len(cka["mha_out"])))

    # Fig 4(a): gradient magnitude per block (claim: block 1 the largest)
    mags = analysis.mha_gradient_magnitudes(params, cfg, batch)
    rank_of_first = sorted(mags, reverse=True).index(mags[0]) + 1
    csv("motivation_fig4a_gradmag", (time.time() - t0) * 1e6,
        "mags=" + "|".join(f"{m:.1f}" for m in mags)
        + f";first_rank={rank_of_first}")

    # Fig 4(b): per-layer ablation perplexity
    base = analysis.ablate_attention_perplexity(params, cfg, batch)
    ppls = [analysis.ablate_attention_perplexity(params, cfg, batch,
                                                 drop_layer=i)
            for i in range(cfg.n_layers)]
    csv("motivation_fig4b_ablation", 0,
        f"base={base:.2f};drops=" + "|".join(f"{p:.2f}" for p in ppls))

    # Fig 3(b): all-connect vs all-mha removal
    no_conn = analysis.ablate_attention_perplexity(params, cfg, batch,
                                                   drop_connections=True)
    no_mha = analysis.ablate_attention_perplexity(params, cfg, batch,
                                                  drop_all_mha=True)
    csv("motivation_fig3b", 0,
        f"orig={base:.2f};all_connect={no_conn:.2f};all_mha={no_mha:.2f}")
