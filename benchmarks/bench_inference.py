"""Paper Apdx D.3 (Fig 19): inference — TTFT (prefill) and per-token decode
latency per connection mode, plus continuous-batching engine throughput."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.decode import ContinuousBatcher, Request, make_serve_step


def bench(csv):
    cfg0 = get_config("gpt2-117m").replace(
        n_layers=6, d_model=256, n_heads=8, n_kv_heads=8, d_ff=1024,
        vocab=2048, max_seq=512, dtype="float32", param_dtype="float32",
        remat=False, attn_block_q=64, attn_block_k=128)
    B, P = 8, 128
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, P), 0, cfg0.vocab)

    def time_decode(serve, params):
        """Prime the cache with one step, then time 20 decode steps; one
        protocol for the sequential and dual measurements."""
        cache = M.init_cache(cfg0, B, 512, "float32")
        nxt, _, cache = serve(params, cache, toks[:, :1],
                              jnp.zeros((B,), jnp.int32))
        t0 = time.time()
        for t in range(1, 21):
            nxt, _, cache = serve(params, cache, nxt[:, None],
                                  jnp.full((B,), t, jnp.int32))
        nxt.block_until_ready()
        return (time.time() - t0) / 20

    base = {}
    for mode in ("preln", "fal"):
        cfg = cfg0.replace(connection=mode)
        params = M.init_params(jax.random.PRNGKey(0), cfg)

        # TTFT: one full prefill forward
        fwd = jax.jit(lambda p, b: M.forward(p, cfg, b, "prefill")[0])
        fwd(params, {"tokens": toks}).block_until_ready()
        t0 = time.time()
        for _ in range(5):
            out = fwd(params, {"tokens": toks})
        out.block_until_ready()
        ttft = (time.time() - t0) / 5
        csv(f"inference_fig19_ttft_{mode}", ttft * 1e6,
            f"batch={B};prompt={P}")

        # decode: per-token latency
        per_tok = time_decode(jax.jit(make_serve_step(cfg)), params)
        base[mode] = per_tok
        csv(f"inference_fig19_decode_{mode}", per_tok * 1e6,
            f"tokens_per_s={B/per_tok:.0f}")

        if mode == "fal":
            # dual-branch decode: MHA||MLP branch-parallel steady-state
            # blocks off the first-attention signal; the delta vs
            # sequential fal decode is the branch overlap
            per_tok_d = time_decode(
                jax.jit(make_serve_step(cfg, dual_branch=True)), params)
            base["dual"] = per_tok_d
            csv("inference_dual_branch_decode", per_tok_d * 1e6,
                f"tokens_per_s={B/per_tok_d:.0f}")
    csv("inference_fig19_speedup", 0,
        f"fal_vs_preln={base['preln']/base['fal']:.3f};"
        f"dual_vs_sequential_fal={base['fal']/base['dual']:.3f}")

    # continuous batching engine throughput
    cfg = cfg0.replace(connection="fal")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatcher(cfg, params, batch_slots=4, max_seq=256)
    rng = np.random.default_rng(0)
    for i in range(8):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 16),
                           max_new=32))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(r.generated) for r in done)
    csv("inference_engine_throughput", dt * 1e6,
        f"requests={len(done)};generated={total};tok_per_s={total/dt:.0f}")
