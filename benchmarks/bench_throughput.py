"""Paper Fig 8: single-device training throughput (tokens/s) per connection
mode.  On CPU the absolute numbers are not TPU-meaningful, but the relative
cost of the extra/removed LNs and the dataflow independence are measured
honestly; the TPU expectation is recorded in EXPERIMENTS.md."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.optim import adamw
from repro.train import step as tstep


def bench(csv, steps=8):
    cfg0 = get_config("gpt2-117m").replace(
        n_layers=6, d_model=256, n_heads=8, n_kv_heads=8, d_ff=1024,
        vocab=2048, max_seq=256, dtype="float32", param_dtype="float32",
        remat=False, attn_block_q=64, attn_block_k=128)
    B, S = 8, 256
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                                          cfg0.vocab)}
    base_tps = None
    for mode in ("preln", "parallel", "fal", "falplus"):
        cfg = cfg0.replace(connection=mode)
        ocfg = adamw.AdamWConfig(lr=1e-4)
        state = tstep.init_state(jax.random.PRNGKey(0), cfg, ocfg)
        step = jax.jit(tstep.make_train_step(cfg, ocfg), donate_argnums=(0,))
        state, _ = step(state, batch)  # compile
        t0 = time.time()
        for _ in range(steps):
            state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.time() - t0) / steps
        tps = B * S / dt
        if mode == "preln":
            base_tps = tps
        csv(f"throughput_fig8_{mode}", dt * 1e6,
            f"tokens_per_s={tps:.0f};speedup_vs_preln={tps/base_tps:.3f}")
