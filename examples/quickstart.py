"""Quickstart: build a FAL model, run a forward pass, train a few steps, and
show the TP all-reduce halving — the paper's contribution in ~60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import tp
from repro.models import model as M
from repro.train import trainer

# ---- 1. a reduced llama3.2 with the paper's FAL connection ----------------
cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
params = M.init_params(jax.random.PRNGKey(0), cfg)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                      cfg.vocab)}
logits, aux, _ = M.forward(params, cfg, batch, "train")
print(f"forward: logits {logits.shape}, FAL connection = {cfg.connection}")

# ---- 2. train a few steps on the synthetic Markov corpus ------------------
state, hist = trainer.train(cfg, steps=30, batch=8, seq_len=64, log_every=10)

# ---- 3. the paper's point: FAL halves per-block TP all-reduces -------------
# make_tp_forward builds REAL DecoderLM blocks and runs them through the
# explicit partial-sum shard_map stack (model.decoder_stack_tp) — the HLO
# below is the production collective structure, not a toy's
mesh = jax.make_mesh((8,), ("model",))
for mode in ("preln", "fal"):
    init, fwd = tp.make_tp_forward(mesh, n_layers=4, d=64, d_ff=256,
                                   n_heads=8, mode=mode)
    p = init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    hlo = fwd.lower(p, x).compile().as_text()
    counts = tp.count_collectives(hlo)
    print(f"{mode:7s}: HLO all-reduces = {counts.get('all-reduce', 0)} "
          f"(scan body counted once; steady-state per block: "
          f"{2 if mode == 'preln' else 1})")
