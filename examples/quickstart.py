"""Quickstart: build a FAL model, run a forward pass, train a few steps, and
show the TP all-reduce halving — the paper's contribution in ~60 lines.

Execution layout is selected with a typed ``ExecutionPlan`` (core/plan.py):
single device, GSPMD mesh, explicit partial-sum TP, or explicit TP with
sequence-parallel LN regions.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import tp
from repro.core.plan import ExecutionPlan
from repro.models import model as M
from repro.train import trainer

# ---- 1. a reduced llama3.2 with the paper's FAL connection ----------------
cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
params = M.init_params(jax.random.PRNGKey(0), cfg)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                      cfg.vocab)}
plan = ExecutionPlan.single_device()          # phase=train, no mesh, no TP
logits, aux, _ = M.forward(params, cfg, batch, plan)
print(f"forward: logits {logits.shape}, FAL connection = {cfg.connection}")

# ---- 2. train a few steps on the synthetic Markov corpus ------------------
state, hist = trainer.train(cfg, steps=30, batch=8, seq_len=64, plan=plan,
                            log_every=10)

# ---- 3. the paper's point: FAL halves per-block TP all-reduces -------------
# make_tp_forward builds REAL DecoderLM blocks and runs them through the
# explicit partial-sum shard_map stack (model.decoder_stack_tp) under an
# ExecutionPlan.from_mesh(mesh, tp="explicit") — the HLO below is the
# production collective structure, not a toy's
mesh = jax.make_mesh((8,), ("model",))
for mode in ("preln", "fal"):
    init, fwd = tp.make_tp_forward(mesh, n_layers=4, d=64, d_ff=256,
                                   n_heads=8, mode=mode)
    p = init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    hlo = fwd.lower(p, x).compile().as_text()
    counts = tp.count_collectives(hlo)
    print(f"{mode:7s}: HLO all-reduces = {counts.get('all-reduce', 0)} "
          f"(scan body counted once; steady-state per block: "
          f"{2 if mode == 'preln' else 1})")

# ---- 4. sequence-parallel LN regions (ExecutionPlan sp=True) ---------------
# same reduce-collective count, but the inter-block activations stay
# sharded over the model axis: each all-reduce becomes a reduce-scatter at
# 1/tp the bytes (block 0 keeps the one all-reduce that exports the
# first-attention signal)
sp_plan = ExecutionPlan.from_mesh(mesh, tp="explicit", sp=True)
# validate raises loud errors for bad head/tp divisibility etc. — the
# 8-head bench stack divides the 8-way model axis; the 4-head reduced
# llama above would be rejected here, not deep inside a shard_map trace
sp_plan.validate(tp.bench_stack_config(4, 64, 256, 8, "fal"))
for mode in ("preln", "fal"):
    init, fwd = tp.make_tp_forward(mesh, n_layers=4, d=64, d_ff=256,
                                   n_heads=8, mode=mode, sp=True)
    p = init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    b = tp.collective_bytes(fwd.lower(p, x).compile().as_text())
    print(f"{mode:7s} sp: reduce-scatter bytes = {b.get('reduce-scatter', 0)}"
          f" (vs all-reduce bytes {b.get('all-reduce', 0)} kept by block 0),"
          f" all-gather bytes = {b.get('all-gather', 0)}")
