"""Serving example: the paged continuous-batching engine over a FAL model —
submits a ragged stream of requests and drains them through fixed batch
slots with ONE token-PACKED dispatch per engine tick: a flat
``(token_budget,)`` buffer where each token carries its lane and position,
so a prefilling lane contributes up to ``prefill_chunk`` tokens and a
decoding lane exactly one in the SAME jitted call — tick FLOPs scale with
live tokens, not a padded slots-by-chunk rectangle, and decode is never
head-of-line blocked behind a prefill dispatch (decode tokens are packed
first).  The example verifies batched outputs match lone-request decoding,
prints the engine's own latency AND packing metrics (TTFT / inter-token /
tokens-per-dispatch / padding-fraction percentiles from its ``repro.obs``
registry), demonstrates the ``max_prefill_tokens`` fairness knob
throttling a prefill burst without changing a single token, captures a
Perfetto-loadable Chrome trace of the run, serves a shared-system-prompt
stream through the radix prefix cache (hits adopt the cached KV pages by
refcount, prefill only their divergent tail, and seed the FAL
first-attention signal from the cached prefix — copy-on-write keeps
sharers bit-identical), and re-serves the stream with
dual-branch (MHA||MLP) decode: under ``fal``/``parallel`` the MLP input
never depends on the block's own attention, so
``EngineConfig(dual_branch=True)`` issues each steady-state block's FFN
off the cached per-slot first-attention signal concurrently with the
paged KV gather — same tokens, overlapped branches.

Run:  PYTHONPATH=src python examples/serve_requests.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan
from repro.kernels.ops import dispatch_paths
from repro.models import model as M
from repro.obs.trace import Tracer, validate_chrome_trace
from repro.serve.scheduler import EngineConfig, PagedEngine, ServeRequest

cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
params = M.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(42)

# --- submit 10 ragged requests through 4 slots -----------------------------
# the engine stores a typed ExecutionPlan (phase is pinned to 'paged' for
# every jitted dispatch it compiles); single_device() = no mesh, no TP.
# The attached Tracer records per-tick/per-dispatch spans and per-request
# lifecycle events (QUEUED -> ADMITTED -> PREFILL -> DECODE -> FINISHED)
plan = ExecutionPlan.single_device()
ecfg = EngineConfig(page_size=8, num_pages=48, slots=4, prefill_chunk=8,
                    max_seq=128)
tracer = Tracer(enabled=True)
engine = PagedEngine(cfg, params, ecfg, plan=plan, tracer=tracer)
prompts = [rng.integers(0, cfg.vocab, 4 + i % 7) for i in range(10)]
for i, p in enumerate(prompts):
    engine.submit(ServeRequest(rid=i, prompt=p, max_new=8 + 3 * (i % 3)))
t0 = time.time()
done = engine.run()
dt = time.time() - t0
total = sum(len(r.generated) for r in done)
st = engine.stats()
print(f"served {len(done)} requests, {total} tokens in {dt:.1f}s "
      f"({total/dt:.0f} tok/s; {st['dispatches']} dispatches in "
      f"{st['ticks']} ticks = {st['dispatches_per_tick']:.2f}/tick, "
      f"occupancy {st['mean_occupancy']:.2f}, "
      f"peak pages {st['pages']['peak_in_use']}/{st['pages']['capacity']})")
print(f"packed ticks: budget {st['token_budget']} tokens/dispatch, live "
      f"p50 {st['tokens_per_dispatch']['p50']:.0f} "
      f"p99 {st['tokens_per_dispatch']['p99']:.0f}, padding fraction "
      f"p50 {st['padding_fraction']['p50']:.2f} (a padded slots-by-chunk "
      f"layout would idle at {1 - 1/ecfg.prefill_chunk:.2f} while decoding)")
print(f"engine-measured latency: ttft p50 {st['ttft_ms']['p50']:.0f}ms "
      f"p99 {st['ttft_ms']['p99']:.0f}ms, inter-token p50 "
      f"{st['inter_token_ms']['p50']:.0f}ms, queue wait p50 "
      f"{st['queue_wait_ticks']['p50']:.1f} ticks")
print(f"kernel dispatch paths (runtime-measured): {dispatch_paths()}")
for r in sorted(done, key=lambda r: r.rid)[:3]:
    print(f"  req {r.rid}: prompt {list(r.prompt)} -> {r.generated}")

# the trace is standard Chrome trace-event JSON: load it at ui.perfetto.dev
n_events = validate_chrome_trace(tracer.export())
tracer.write("TRACE_example.json")
print(f"wrote TRACE_example.json ({n_events} events; "
      f"open in ui.perfetto.dev)")

# --- correctness: batched == lone ------------------------------------------
lone = PagedEngine(cfg, params, EngineConfig(page_size=8, num_pages=48,
                                             slots=1, prefill_chunk=8,
                                             max_seq=128), plan=plan)
probe = sorted(done, key=lambda r: r.rid)[0]
lone.submit(ServeRequest(rid=0, prompt=probe.prompt,
                         max_new=len(probe.generated)))
ref = lone.run()[0].generated
assert ref == probe.generated, (ref, probe.generated)
print("continuous batching == lone decoding ✓")

# --- fairness knob: cap prefill tokens per tick ----------------------------
# a burst of long prompts would claim most of the token budget every tick;
# max_prefill_tokens caps the PREFILL share (decode tokens are packed
# first and never displaced), trading prefill throughput for inter-token
# latency — pacing changes, tokens never do
burst_prompts = [rng.integers(0, cfg.vocab, 40 + 8 * i) for i in range(6)]


def serve_burst(max_prefill):
    eng = PagedEngine(cfg, params,
                      EngineConfig(page_size=8, num_pages=64, slots=4,
                                   prefill_chunk=8, max_seq=128,
                                   max_prefill_tokens=max_prefill),
                      plan=plan)
    for i, p in enumerate(burst_prompts):
        eng.submit(ServeRequest(rid=i, prompt=p, max_new=10))
    out = {r.rid: r.generated for r in eng.run()}
    return out, eng.stats()


uncapped, st_u = serve_burst(0)
capped, st_c = serve_burst(4)
assert capped == uncapped
print(f"fairness knob: max_prefill_tokens=4 stretches the burst over "
      f"{st_c['ticks']} ticks (vs {st_u['ticks']} uncapped), live "
      f"tokens/dispatch p50 {st_c['tokens_per_dispatch']['p50']:.0f} vs "
      f"{st_u['tokens_per_dispatch']['p50']:.0f} — identical tokens ✓")

# --- prefix cache: shared system prompt over copy-on-write KV pages --------
# EngineConfig(prefix_cache=True) keeps a radix tree over page-aligned
# prompt prefixes: the first request to finish parks its KV pages (and the
# FAL first-attention signal a1_sig) in the tree; later requests sharing
# the system prompt adopt those pages by refcount and prefill only their
# divergent tail.  A full-prompt hit enters decode on its very first tick
# with the a1_sig seeded from the cache.  Writes into a shared page go
# copy-on-write first, so sharers never see each other's tokens.
sys_prompt = rng.integers(0, cfg.vocab, 40)        # 5 pages at page_size 8
hot = PagedEngine(cfg, params,
                  EngineConfig(page_size=8, num_pages=64, slots=4,
                               prefill_chunk=8, max_seq=128,
                               prefix_cache=True),
                  plan=plan)
hot.submit(ServeRequest(rid=0, prompt=np.concatenate(
    [sys_prompt, rng.integers(0, cfg.vocab, 4)]), max_new=6))
hot.run()                        # the cold donor: finishing parks the prefix
tails = [rng.integers(0, cfg.vocab, 3 + i) for i in range(5)]
for i, tail in enumerate(tails):
    hot.submit(ServeRequest(rid=1 + i,
                            prompt=np.concatenate([sys_prompt, tail]),
                            max_new=6))
hot.run()
stp = hot.stats()["prefix"]
pg = hot.stats()["pages"]
print(f"prefix cache: {stp['hits']}/{stp['hits'] + stp['misses']} "
      f"admissions hit ({stp['hit_rate']:.2f}), hit length p50 "
      f"{stp['hit_tokens']['p50']:.0f} tokens; {pg['shares']} page-shares "
      f"vs {pg['allocs']} pages allocated, {stp['cow_copies']} COW "
      f"copies; ttft p50 hot {stp['ttft_hit_ticks']['p50']:.0f} ticks vs "
      f"cold {stp['ttft_cold_ticks']['p50']:.0f} ticks")

# hot tokens are bit-identical to an engine that never shared a page
ref_eng = PagedEngine(cfg, params,
                      EngineConfig(page_size=8, num_pages=64, slots=4,
                                   prefill_chunk=8, max_seq=128),
                      plan=plan)
ref_eng.submit(ServeRequest(rid=1, prompt=np.concatenate(
    [sys_prompt, tails[0]]), max_new=6))
hit_probe = next(r for r in hot.finished if r.rid == 1)
assert ref_eng.run()[0].generated == hit_probe.generated
print("prefix-hit decoding == cold decoding ✓")

# a request whose WHOLE prompt is cached never prefills: it is admitted
# straight into the decode lane (its last page COW'd for the first write)
before = hot.stats()["prefill_tokens"]
hot.submit(ServeRequest(rid=9, prompt=sys_prompt.copy(), max_new=6))
hot.run()
print(f"full-prompt hit: {hot.stats()['prefill_tokens'] - before} prefill "
      f"tokens dispatched (entered decode on its first tick)")

# --- quantized KV pages: int8 storage + per-page-row fp32 scales -----------
# EngineConfig(kv_dtype="int8") stores every layer's K/V page pools at one
# byte per element plus a (num_pages, page_size) fp32 scale pool — one
# amax/127 scale per cached token row, shared across KV heads.  The paged
# kernels dequantize at the VMEM load and accumulate softmax in fp32, so
# the quality cost is a bounded logit perturbation while the page pool
# shrinks ~4x vs float32 (~2x vs bf16): at equal num_pages that is ~2x
# concurrent requests per HBM byte (the capacity knob behind
# preemption-by-page-pressure).  The scales are history-free — a row's
# scale depends only on that row's values — so COW page copies and radix
# prefix-cache shares stay bit-exact and the prefix/spec suites run
# unchanged under quantization.
quant = PagedEngine(cfg, params,
                    EngineConfig(page_size=8, num_pages=48, slots=4,
                                 prefill_chunk=8, max_seq=128,
                                 kv_dtype="int8"),
                    plan=plan)
for i, p in enumerate(prompts):
    quant.submit(ServeRequest(rid=i, prompt=p, max_new=8 + 3 * (i % 3)))
done_q = quant.run()
pq, pf = quant.stats()["pages"], engine.stats()["pages"]
print(f"quantized engine (kv_dtype=int8): {pq['page_bytes']} bytes/page vs "
      f"{pf['page_bytes']} float32 ({pf['page_bytes']/pq['page_bytes']:.1f}x "
      f"more requests per HBM byte at equal num_pages); peak KV bytes "
      f"{pq['peak_bytes_in_use']} vs {pf['peak_bytes_in_use']}; quantized "
      f"dispatches trace as "
      f"{[s for s in dispatch_paths() if s.endswith('.int8')]}")

# --- dual-branch decode: MHA||MLP off the cached FAL signal ----------------
# valid only for fal/parallel-family connections (ExecutionPlan.validate
# rejects preln/falplus loudly); on the CPU dispatch path logits — and
# therefore tokens — are bit-identical to the sequential engine (the fused
# TPU kernel is tolerance-close), the win is branch overlap.  Dual rides
# the same ONE-dispatch-per-tick packed program: steady-state blocks issue
# their FFN off the cached first-attention signal concurrently with the
# paged KV gather inside that single jitted call.
dual = PagedEngine(cfg, params,
                   EngineConfig(page_size=8, num_pages=48, slots=4,
                                prefill_chunk=8, max_seq=128,
                                dual_branch=True),
                   plan=plan)
for i, p in enumerate(prompts):
    dual.submit(ServeRequest(rid=i, prompt=p, max_new=8 + 3 * (i % 3)))
t0 = time.time()
done_dual = dual.run()
dt_dual = time.time() - t0
from repro.kernels.ops import _default_use_pallas
if not _default_use_pallas():
    assert ({r.rid: r.generated for r in done_dual}
            == {r.rid: r.generated for r in done})
    print(f"dual-branch engine == sequential tokens ✓ "
          f"({total/dt_dual:.0f} tok/s vs {total/dt:.0f} sequential)")
else:
    print(f"dual-branch engine: {total/dt_dual:.0f} tok/s vs "
          f"{total/dt:.0f} sequential (fused TPU kernel path)")
