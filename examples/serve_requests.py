"""Serving example: the paged continuous-batching engine over a FAL model —
submits a ragged stream of requests and drains them through fixed batch
slots with ONE mixed (slots, prefill_chunk) dispatch per engine tick
(``EngineConfig.mixed_ticks``, the default): prefilling lanes advance up to
a chunk of prompt tokens while decoding lanes advance one sampled token in
the SAME jitted call, so decode is never head-of-line blocked behind a
prefill dispatch.  The example verifies batched outputs match lone-request
decoding, compares against the retired two-program engine
(``mixed_ticks=False``: a prefill dispatch then a decode dispatch per
tick), and re-serves the stream with dual-branch (MHA||MLP) decode: under
``fal``/``parallel`` the MLP input never depends on the block's own
attention, so ``EngineConfig(dual_branch=True)`` issues each steady-state
block's FFN off the cached per-slot first-attention signal concurrently
with the paged KV gather — same tokens, overlapped branches.

Run:  PYTHONPATH=src python examples/serve_requests.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.plan import ExecutionPlan
from repro.models import model as M
from repro.serve.scheduler import EngineConfig, PagedEngine, ServeRequest

cfg = get_config("llama3.2-3b").reduced().replace(connection="fal")
params = M.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(42)

# --- submit 10 ragged requests through 4 slots -----------------------------
# the engine stores a typed ExecutionPlan (phase is pinned to 'paged' for
# every jitted dispatch it compiles); single_device() = no mesh, no TP.
# mixed_ticks=True (default): the engine compiles exactly ONE program
plan = ExecutionPlan.single_device()
ecfg = EngineConfig(page_size=8, num_pages=48, slots=4, prefill_chunk=8,
                    max_seq=128)
engine = PagedEngine(cfg, params, ecfg, plan=plan)
prompts = [rng.integers(0, cfg.vocab, 4 + i % 7) for i in range(10)]
for i, p in enumerate(prompts):
    engine.submit(ServeRequest(rid=i, prompt=p, max_new=8 + 3 * (i % 3)))
t0 = time.time()
done = engine.run()
dt = time.time() - t0
total = sum(len(r.generated) for r in done)
st = engine.stats()
print(f"served {len(done)} requests, {total} tokens in {dt:.1f}s "
      f"({total/dt:.0f} tok/s; {st['dispatches']} dispatches in "
      f"{st['ticks']} ticks = {st['dispatches_per_tick']:.2f}/tick, "
      f"occupancy {st['mean_occupancy']:.2f}, "
      f"peak pages {st['pages']['peak_in_use']}/{st['pages']['capacity']})")
for r in sorted(done, key=lambda r: r.rid)[:3]:
    print(f"  req {r.rid}: prompt {list(r.prompt)} -> {r.generated}")

# --- correctness: batched == lone ------------------------------------------
lone = PagedEngine(cfg, params, EngineConfig(page_size=8, num_pages=48,
                                             slots=1, prefill_chunk=8,
                                             max_seq=128), plan=plan)
probe = sorted(done, key=lambda r: r.rid)[0]
lone.submit(ServeRequest(rid=0, prompt=probe.prompt,
                         max_new=len(probe.generated)))
ref = lone.run()[0].generated
assert ref == probe.generated, (ref, probe.generated)
print("continuous batching == lone decoding ✓")

# --- mixed tick == retired two-program engine ------------------------------
# one release of back-compat: mixed_ticks=False compiles the (slots, chunk)
# prefill and (slots, 1) decode programs and issues up to two dispatches
# per tick; token streams must be identical
two = PagedEngine(cfg, params,
                  EngineConfig(page_size=8, num_pages=48, slots=4,
                               prefill_chunk=8, max_seq=128,
                               mixed_ticks=False), plan=plan)
for i, p in enumerate(prompts):
    two.submit(ServeRequest(rid=i, prompt=p, max_new=8 + 3 * (i % 3)))
done_two = two.run()
assert ({r.rid: r.generated for r in done_two}
        == {r.rid: r.generated for r in done})
st2 = two.stats()
print(f"mixed tick == two-dispatch engine ✓ "
      f"({st['dispatches_per_tick']:.2f} vs "
      f"{st2['dispatches_per_tick']:.2f} dispatches/tick)")

# --- dual-branch decode: MHA||MLP off the cached FAL signal ----------------
# valid only for fal/parallel-family connections (ExecutionPlan.validate
# rejects preln/falplus loudly); on the CPU dispatch path logits — and
# therefore tokens — are bit-identical to the sequential engine (the fused
# TPU kernel is tolerance-close), the win is branch overlap.  The fused
# C == 1 dual Pallas dispatch only exists on the two-program path's decode
# tick, so this engine pins mixed_ticks=False (under mixed ticks the
# branches still overlap, at op level)
dual = PagedEngine(cfg, params,
                   EngineConfig(page_size=8, num_pages=48, slots=4,
                                prefill_chunk=8, max_seq=128,
                                dual_branch=True, mixed_ticks=False),
                   plan=plan)
for i, p in enumerate(prompts):
    dual.submit(ServeRequest(rid=i, prompt=p, max_new=8 + 3 * (i % 3)))
t0 = time.time()
done_dual = dual.run()
dt_dual = time.time() - t0
from repro.kernels.ops import _default_use_pallas
if not _default_use_pallas():
    assert ({r.rid: r.generated for r in done_dual}
            == {r.rid: r.generated for r in done})
    print(f"dual-branch engine == sequential tokens ✓ "
          f"({total/dt_dual:.0f} tok/s vs {total/dt:.0f} sequential)")
else:
    print(f"dual-branch engine: {total/dt_dual:.0f} tok/s vs "
          f"{total/dt:.0f} sequential (fused TPU kernel path)")
