"""End-to-end driver: train a ~100M-param GPT-2-style model for a few hundred
steps with each connection mode (paper Table 1 / Fig 9 analogue at laptop
scale) and compare loss curves + step time.

Run:  PYTHONPATH=src python examples/train_fal_vs_baseline.py [--steps 300]
"""
import argparse
import json
import os
import time

import jax

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticMarkov, unigram_entropy
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-117m", action="store_true",
                    help="use the full GPT-2 117M config (slow on CPU)")
    args = ap.parse_args()

    cfg0 = get_config("gpt2-117m")
    if not args.full_117m:
        # ~8M params: 6 layers x 256 — big enough for mode separation,
        # small enough for CPU
        cfg0 = cfg0.replace(n_layers=6, d_model=256, n_heads=8,
                            n_kv_heads=8, d_ff=1024, vocab=2048,
                            max_seq=args.seq, dtype="float32",
                            param_dtype="float32", remat=False,
                            attn_block_q=64, attn_block_k=128)

    data = SyntheticMarkov(cfg0.vocab, args.seq, args.batch, seed=7)
    print(f"unigram entropy floor: {unigram_entropy(data):.3f} nats")

    results = {}
    for mode in ("preln", "parallel", "fal", "falplus"):
        cfg = cfg0.replace(connection=mode)
        t0 = time.time()
        state, hist = trainer.train(cfg, steps=args.steps, batch=args.batch,
                                    seq_len=args.seq, data=data,
                                    log_every=max(args.steps // 5, 1),
                                    schedule="onecycle", lr=1e-3)
        results[mode] = {"final_loss": hist[-1]["loss"],
                         "wall_s": round(time.time() - t0, 1),
                         "curve": [(h["step"], round(h["loss"], 4))
                                   for h in hist]}
        print(f"--> {mode:9s} final {hist[-1]['loss']:.4f} "
              f"({results[mode]['wall_s']}s)\n")

    print(json.dumps({m: {k: v for k, v in r.items() if k != 'curve'}
                      for m, r in results.items()}, indent=1))
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/train_fal_vs_baseline.json", "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
