"""Train step: loss -> grad (with microbatched gradient accumulation) ->
AdamW update.  Built once per (cfg, ExecutionPlan) and jitted by the caller
(launch/train.py, launch/dryrun.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.plan import ExecutionPlan, Phase
from repro.models import model as M
from repro.optim import adamw


def make_loss_fn(cfg, plan=None):
    plan = ExecutionPlan.resolve(plan)

    def loss(params, batch):
        l, metrics = M.loss_fn(params, cfg, batch, plan)
        return l, metrics
    return loss


def make_train_step(cfg, ocfg: adamw.AdamWConfig, plan=None,
                    num_microbatches: int = 1, grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt"}.  ``batch["tokens"]``: (B, S); B is split into
    ``num_microbatches`` sequential microbatches (lax.scan) with gradient
    accumulation — bounds activation (and MoE dispatch-buffer) memory.
    ``plan`` (ExecutionPlan) flows
    unchanged into the model: with ``tp='explicit'`` the decoder family's
    loss/grad run through the shard_map partial-sum TP stack
    (model.decoder_stack_tp) — the paper's per-block collective structure —
    instead of implicit GSPMD sharding, and with ``sp=True`` the
    inter-block activations additionally stay sequence-sharded over the
    model axis (reduce-scatter/all-gather LN regions); the collectives
    differentiate, so the same step covers every layout.
    ``grad_shardings``: NamedSharding tree matching params — pins the
    accumulated-gradient buffer to the param layout (otherwise GSPMD may
    replicate it, which at 671B scale is fatal).
    """
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.TRAIN)
    plan.validate(cfg)
    loss_fn = make_loss_fn(cfg, plan)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def pin(g):
        if grad_shardings is None:
            return g
        return jax.lax.with_sharding_constraint(g, grad_shardings)

    def train_step(state, batch):
        params = state["params"]

        if num_microbatches == 1:
            (l, metrics), grads = grad_fn(params, batch)
            grads = pin(grads)
        else:
            def split(x):
                return x.reshape((num_microbatches, -1) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_new = pin(jax.tree.map(jnp.add, g_acc, pin(g)))
                return (g_new, l_acc + l), None

            g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params))
            (g_sum, l_sum), _ = jax.lax.scan(acc, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, g_sum)
            l = l_sum / num_microbatches
            metrics = {}

        new_params, new_opt, gnorm = adamw.adamw_update(
            params, grads, state["opt"], ocfg)
        metrics = dict(metrics, loss=l, grad_norm=gnorm)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_state(key, cfg, ocfg: adamw.AdamWConfig):
    params = M.init_params(key, cfg)
    return {"params": params, "opt": adamw.init_opt_state(params, ocfg)}


def make_eval_step(cfg, plan=None):
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.EVAL)
    plan.validate(cfg)
    loss_fn = make_loss_fn(cfg, plan)

    def eval_step(params, batch):
        l, metrics = loss_fn(params, batch)
        return dict(metrics, loss=l, ppl=jnp.exp(metrics["ce"]))
    return eval_step
