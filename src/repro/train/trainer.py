"""Training loop driver: data -> jitted train_step -> logging/eval/ckpt."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import SyntheticMarkov
from repro.optim import adamw, schedules
from repro.train import checkpoint as ckpt
from repro.train import step as tstep


def train(cfg, *, steps=200, batch=8, seq_len=128, lr=3e-4, seed=0,
          plan=None, num_microbatches=1, log_every=20,
          eval_every=0, ckpt_dir=None, data=None, schedule="cosine",
          in_shardings=None, callbacks=()):
    """Returns (state, history).  ``plan``: ExecutionPlan (or legacy
    parallel-ctx dict, shimmed) selecting the mesh/TP/SP layout."""
    sched = {"cosine": schedules.warmup_cosine,
             "onecycle": schedules.one_cycle,
             "wsd": schedules.wsd}[schedule](lr, steps)
    ocfg = adamw.AdamWConfig(lr=sched)
    state = tstep.init_state(jax.random.PRNGKey(seed), cfg, ocfg)
    step_fn = jax.jit(tstep.make_train_step(cfg, ocfg, plan,
                                            num_microbatches),
                      in_shardings=in_shardings, donate_argnums=(0,))
    eval_fn = jax.jit(tstep.make_eval_step(cfg, plan))
    if data is None:
        data = SyntheticMarkov(cfg.vocab, seq_len, batch, seed=seed)
    it = iter(data)
    history = []
    t0 = time.time()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, b)
        if (log_every and i % log_every == 0) or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m.update(step=i, wall=time.time() - t0)
            history.append(m)
            if log_every:
                print(f"step {i:5d} loss {m['loss']:.4f} "
                      f"gnorm {m.get('grad_norm', 0):.2f} "
                      f"({m['wall']:.1f}s)", flush=True)
        if eval_every and i and i % eval_every == 0:
            eb = {k: jnp.asarray(v) for k, v in data.batch_at(10**6 + i).items()}
            em = eval_fn(state["params"], eb)
            print(f"  eval ppl {float(em['ppl']):.3f}", flush=True)
        for cb in callbacks:
            cb(i, state, metrics)
    if ckpt_dir:
        ckpt.save(ckpt_dir, state, step=steps,
                  meta={"arch": cfg.arch_id, "connection": cfg.connection})
    return state, history
