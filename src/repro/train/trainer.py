"""Training loop driver: data -> jitted train_step -> logging/eval/ckpt.

Per-step metrics flow through the same ``repro.obs.MetricsRegistry`` the
serving engines report into: every logged scalar from the jitted step
(loss, grad_norm, ...) lands in a gauge, step wall time in a log-bucket
histogram, so a training run exports the identical JSON/Prometheus shapes
as a serving run and the benchmark harness stamps both the same way.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import SyntheticMarkov
from repro.obs.metrics import MetricsRegistry
from repro.optim import adamw, schedules
from repro.train import checkpoint as ckpt
from repro.train import step as tstep

_SITE = "train/trainer.py"


def train(cfg, *, steps=200, batch=8, seq_len=128, lr=3e-4, seed=0,
          plan=None, num_microbatches=1, log_every=20,
          eval_every=0, ckpt_dir=None, data=None, schedule="cosine",
          in_shardings=None, callbacks=(), metrics=None):
    """Returns (state, history).  ``plan``: ExecutionPlan selecting the
    mesh/TP/SP layout.  ``metrics``: a ``repro.obs.MetricsRegistry`` to
    record into (one is created per run when omitted; read it back via
    ``history`` consumers or pass a shared registry)."""
    reg = metrics if metrics is not None else MetricsRegistry()
    c_steps = reg.counter("train_steps_total", unit="steps", site=_SITE)
    c_tokens = reg.counter("train_tokens_total", unit="tokens", site=_SITE)
    h_step_ms = reg.histogram("train_step_ms", unit="ms", site=_SITE)
    sched = {"cosine": schedules.warmup_cosine,
             "onecycle": schedules.one_cycle,
             "wsd": schedules.wsd}[schedule](lr, steps)
    ocfg = adamw.AdamWConfig(lr=sched)
    state = tstep.init_state(jax.random.PRNGKey(seed), cfg, ocfg)
    step_fn = jax.jit(tstep.make_train_step(cfg, ocfg, plan,
                                            num_microbatches),
                      in_shardings=in_shardings, donate_argnums=(0,))
    eval_fn = jax.jit(tstep.make_eval_step(cfg, plan))
    if data is None:
        data = SyntheticMarkov(cfg.vocab, seq_len, batch, seed=seed)
    it = iter(data)
    history = []
    t0 = time.time()
    t_prev = time.perf_counter()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics_out = step_fn(state, b)
        t_now = time.perf_counter()
        c_steps.inc()
        c_tokens.inc(int(np.prod(b["tokens"].shape)))
        h_step_ms.record((t_now - t_prev) * 1e3)
        t_prev = t_now
        if (log_every and i % log_every == 0) or i == steps - 1:
            m = {k: float(v) for k, v in metrics_out.items()}
            for k, v in m.items():
                reg.gauge(f"train_{k}", site=_SITE).set(v)
            m.update(step=i, wall=time.time() - t0)
            history.append(m)
            if log_every:
                print(f"step {i:5d} loss {m['loss']:.4f} "
                      f"gnorm {m.get('grad_norm', 0):.2f} "
                      f"({m['wall']:.1f}s)", flush=True)
        if eval_every and i and i % eval_every == 0:
            eb = {k: jnp.asarray(v) for k, v in data.batch_at(10**6 + i).items()}
            em = eval_fn(state["params"], eb)
            reg.gauge("train_eval_ppl", site=_SITE).set(float(em["ppl"]))
            print(f"  eval ppl {float(em['ppl']):.3f}", flush=True)
        for cb in callbacks:
            cb(i, state, metrics_out)
    if ckpt_dir:
        ckpt.save(ckpt_dir, state, step=steps,
                  meta={"arch": cfg.arch_id, "connection": cfg.connection})
    return state, history
