"""Checkpointing: flat-key npz + json manifest (no external deps).

Arrays are gathered to host (fine for the CPU/laptop scale this container
runs; on a real pod you would swap the np.savez for per-host sharded IO —
the manifest format already records the tree structure needed to do so).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save(path, state, step=0, meta=None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    np.savez(os.path.join(path, f"ckpt_{step}.npz"), **flat)
    manifest = {"step": step, "keys": sorted(flat),
                "meta": meta or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def latest_step(path):
    if not os.path.isfile(os.path.join(path, "manifest.json")):
        return None
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]


def restore(path, like, step=None):
    """Restore into the structure of ``like`` (dtypes/shapes validated)."""
    step = latest_step(path) if step is None else step
    data = np.load(os.path.join(path, f"ckpt_{step}.npz"))

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(rebuild(v, f"{prefix}{i}/")
                              for i, v in enumerate(tree))
        arr = data[prefix[:-1]]
        assert arr.shape == tuple(tree.shape), (prefix, arr.shape, tree.shape)
        return jnp.asarray(arr, dtype=tree.dtype)

    return rebuild(like)
