"""Production mesh + PartitionSpec trees.

Single pod: (16, 16) over ("data", "model") — 256 chips (v5e pod).
Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips.  The
``pod`` axis composes with ``data`` (pure DP across pods): only the gradient
all-reduce crosses pods, never TP collectives.

``param_specs`` mirrors any model's param pytree with Megatron-style specs:
attention heads + FFN hidden over ``model`` (column/row), vocab over
``model``, MoE experts over ``model`` (expert parallelism), Mamba mixers
replicated over ``model`` (sharded over batch only; DESIGN.md §4).  Stacked
(scan) parameter trees get leading ``None``s automatically.

These specs serve double duty: GSPMD layout hints for the implicit path,
and the shard_map ``in_specs`` of the explicit partial-sum TP stack
(``models/model.py::decoder_stack_tp`` — select it with
``core.plan.ExecutionPlan.from_mesh(mesh, tp="explicit")``; add ``sp=True``
for the sequence-parallel LN-region layout, where the activation specs put
the sequence dim on ``model`` instead of replicating it).  The column/row
orientation is what makes the blocks' local kernels return partial sums
there.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


MODEL = "model"

# base (unstacked) ndim and spec per leaf key; stacking prepends Nones.
# column = output-dim sharded; row = input-dim sharded (Megatron).
_BASE = {
    # embeddings / heads
    "emb": (2, P(MODEL, None)),
    "pos_emb": (2, P()),
    "enc_pos": (2, P()),
    # norms / scalars / ssm per-head params
    "scale": (1, P()), "bias": (1, P()),
    "a_log": (1, P()), "D": (1, P()), "dt_bias": (1, P()),
    "conv_b": (1, P()), "conv_w": (2, P()),
    # attention (GQA)
    "wq": (2, P(None, MODEL)), "wk": (2, P(None, MODEL)),
    "wv": (2, P(None, MODEL)),
    "wo": (2, P(MODEL, None)),
    # MLA: down-projections replicated (small), up-projections column
    "w_dq": (2, P()), "w_dkv": (2, P()), "w_kr": (2, P()),
    "w_uq": (2, P(None, MODEL)), "w_uk": (2, P(None, MODEL)),
    "w_uv": (2, P(None, MODEL)),
    # dense mlp
    "wi": (2, P(None, MODEL)), "wg": (2, P(None, MODEL)),
    "wo2": (2, P(MODEL, None)),
    # mamba (replicated over model; batch-parallel only)
    "in_proj": (2, P()), "out_proj": (2, P()),
    # generic dense_init {'w': ...}
    "w": (2, P()),
}

_MOE = {
    "router": (2, P()),
    "wi": (3, P(MODEL, None, None)),
    "wg": (3, P(MODEL, None, None)),
    "wo": (3, P(MODEL, None, None)),
}


_FSDP_MIN_DIM = 1024  # don't FSDP-shard small dims


def _add_fsdp(spec, shape, fsdp_axes):
    """ZeRO-3/FSDP-in-GSPMD: also shard the largest unsharded dim over the
    data axes.  GSPMD inserts the per-layer all-gather inside the scan loop
    (the standard MaxText pattern); the shard_map MoE path receives weights
    via in_specs P('model',...) so jit re-gathers them there automatically.
    """
    if not fsdp_axes:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    cands = [i for i, (p, s) in enumerate(zip(parts, shape))
             if p is None and s >= _FSDP_MIN_DIM]
    if not cands:
        return spec
    i = max(cands, key=lambda i: shape[i])
    parts[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    return P(*parts)


def _leaf_spec(key, leaf, in_moe, parent, fsdp_axes):
    if key == "w":
        # generic dense_init leaf: shard the LM head column-wise, keep the
        # small projections (mtp proj, etc.) replicated
        spec = P(None, MODEL) if parent == "head" else P()
        return _add_fsdp(spec, leaf.shape, fsdp_axes) \
            if parent == "head" else spec
    table = _MOE if in_moe and key in _MOE else _BASE
    if key not in table:
        return P()
    base_nd, spec = table[key]
    extra = leaf.ndim - base_nd
    if extra < 0:
        return P()
    full = P(*([None] * extra + list(spec)))
    if key in ("scale", "bias", "a_log", "D", "dt_bias", "conv_b", "conv_w",
               "pos_emb", "enc_pos",
               # embeddings: FSDP on the feature dim makes the token gather
               # unpartitionable (involuntary full remat in SPMD) — the
               # vocab-sharded table is small enough per device already
               "emb"):
        return full
    return _add_fsdp(full, leaf.shape, fsdp_axes)


def param_specs(params, cfg=None, fsdp_axes=(), kv_replicated=False):
    """``kv_replicated``: keep wk/wv whole on every model shard — the
    Megatron GQA fallback when n_kv_heads < tp_size, used by the explicit-TP
    stack (each device computes all KV heads and slices its group's one;
    models/attention.py)."""
    fsdp_axes = tuple(fsdp_axes)

    def walk(node, key=None, in_moe=False, parent=None):
        if isinstance(node, dict):
            moe_here = "router" in node
            return {k: walk(v, k,
                            # the shared expert is a plain dense MLP — do
                            # NOT apply expert sharding to its stack dim
                            (in_moe or moe_here) and k != "shared",
                            key)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, key, in_moe, parent) for v in node)
        if kv_replicated and key in ("wk", "wv"):
            return P()
        return _leaf_spec(key, node, in_moe, parent, fsdp_axes)
    return walk(params)


def state_specs(state, cfg=None, fsdp_axes=()):
    ps = param_specs(state["params"], cfg, fsdp_axes)
    return {"params": ps,
            "opt": {"m": ps, "v": ps, "count": P()}}


# --------------------------------------------------------------------------- #
def _div(n, size):
    return n % size == 0


def batch_spec(mesh):
    return P(data_axes_of(mesh))


def cache_specs(cfg, mesh, batch):
    """Decode-cache specs.  batch over data when divisible, else the KV
    sequence takes the data axes (long_500k, batch=1).  KV heads over
    ``model`` when divisible, else the sequence also takes ``model``
    (sequence-parallel decode attention — GSPMD inserts the partial-softmax
    combine)."""
    dax = data_axes_of(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dax]))
    msize = mesh.shape[MODEL]
    batch_ok = _div(batch, dsize)
    b_ax = dax if batch_ok else None
    s_data = None if batch_ok else dax

    def kv4(hkv):  # (B, S, Hkv, Dh)
        h_ax = MODEL if _div(hkv, msize) else None
        s_ax = s_data if h_ax else (
            (tuple(dax) + (MODEL,)) if s_data else MODEL)
        return P(b_ax, s_ax, h_ax, None)

    def lat3(_):   # (B, S, R) compressed latent (MLA) — no head dim
        s_ax = (tuple(dax) + (MODEL,)) if s_data else MODEL
        return P(b_ax, s_ax, None)

    def walk(node, key=None):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        nd = node.ndim
        if key in ("k", "v"):
            base = kv4(node.shape[-2])
            return P(*([None] * (nd - 4) + list(base)))
        if key in ("c", "kr"):
            base = lat3(None)
            return P(*([None] * (nd - 3) + list(base)))
        if key == "state":  # mamba (B, H, P, N)
            return P(*([None] * (nd - 4) + [b_ax, None, None, None]))
        if key == "conv":   # (B, K-1, C)
            return P(*([None] * (nd - 3) + [b_ax, None, None]))
        if key == "enc_out":
            return P(b_ax, None, None)
        return P()
    return walk


def shardings_for(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
