"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation.  This is what the multi-pod dry-run lowers
against."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as MX
from repro.models import model as M
from repro.optim import adamw
from repro.train import step as tstep


def _sds(tree, shardings):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        tree, shardings)


def dryrun_overrides(cfg, shape_cfg):
    """Numerics/memory policy for full-scale dry runs (DESIGN.md §4/§7)."""
    over = dict(dtype="bfloat16")
    if cfg.arch_id.startswith("deepseek"):
        # 671B: bf16 params + bf16 adam moments (or nothing fits anywhere)
        over.update(param_dtype="bfloat16")
    else:
        over.update(param_dtype="float32")
    return cfg.replace(**over)


def opt_cfg_for(cfg):
    return adamw.AdamWConfig(
        lr=1e-4,
        state_dtype="bfloat16" if cfg.arch_id.startswith("deepseek")
        else "float32")


def num_microbatches(cfg, shape_cfg, mesh):
    if shape_cfg.mode != "train":
        return 1
    if os.environ.get("REPRO_MICROBATCHES"):
        return int(os.environ["REPRO_MICROBATCHES"])
    big = cfg.n_experts > 0 or cfg.d_model >= 4096
    n = 8 if big else 4
    # microbatch size must still cover the batch shards
    shards = int(np.prod([mesh.shape[a] for a in MX.data_axes_of(mesh)]))
    while shape_cfg.global_batch // n < shards and n > 1:
        n //= 2
    return n


def batch_struct(cfg, shape_cfg, mesh):
    """Abstract input batch for the given shape."""
    dspec = P(MX.data_axes_of(mesh))
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    sh = lambda spec: NamedSharding(mesh, spec)
    out = {"tokens": jax.ShapeDtypeStruct(
        (B, S), jnp.int32, sharding=sh(P(*dspec, None)))}
    if cfg.family == "vlm" and shape_cfg.mode != "decode":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16,
            sharding=sh(P(*dspec, None, None)))
    if cfg.family == "audio" and shape_cfg.mode != "decode":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_enc_frames, cfg.d_model), jnp.bfloat16,
            sharding=sh(P(*dspec, None, None)))
    return out


def train_input_specs(cfg, shape_cfg, mesh, fsdp_axes=()):
    """(state_sds, batch_sds) for jit(train_step).lower."""
    ocfg = opt_cfg_for(cfg)
    state_shape = jax.eval_shape(
        lambda: tstep.init_state(jax.random.PRNGKey(0), cfg, ocfg))
    specs = MX.state_specs(state_shape, cfg, fsdp_axes)
    shardings = MX.shardings_for(mesh, specs)
    state_sds = _sds(state_shape, shardings)
    return state_sds, batch_struct(cfg, shape_cfg, mesh)


def decode_input_specs(cfg, shape_cfg, mesh, fsdp_axes=()):
    """(params_sds, cache_sds, tokens_sds, pos_sds) for serve_step.lower."""
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    params_shape = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = MX.param_specs(params_shape, cfg, fsdp_axes)
    params_sds = _sds(params_shape, MX.shardings_for(mesh, pspecs))

    cache_shape = jax.eval_shape(
        lambda: M.init_cache(cfg, B, S, "bfloat16"))
    cspec_fn = MX.cache_specs(cfg, mesh, B)
    cspecs = cspec_fn(cache_shape)
    cache_sds = _sds(cache_shape, MX.shardings_for(mesh, cspecs))

    dax = MX.data_axes_of(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dax]))
    bspec = P(dax) if B % dsize == 0 else P()
    sh = NamedSharding(mesh, bspec)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                  sharding=NamedSharding(mesh, P(*bspec, None)))
    pos = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=sh)
    return params_sds, cache_sds, tokens, pos
