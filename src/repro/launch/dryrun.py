"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against ShapeDtypeStruct inputs and record memory/cost/collective
analyses for EXPERIMENTS.md §Dry-run and the §Roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config, \
    shape_applicable
from repro.core.plan import ExecutionPlan, Phase
from repro.launch import mesh as MX
from repro.launch import specs as SP
from repro.serve.decode import make_serve_step
from repro.train import step as tstep


def _fsdp_axes(cfg, mesh):
    # FSDP params over the data axes for every arch (MaxText default);
    # pure-TP is available via --no-fsdp for the perf ablations.
    return MX.data_axes_of(mesh)


def lower_pair(arch, shape_name, mesh, *, connection=None, fsdp=True,
               extra_overrides=None, tp="gspmd", sp=False):
    """Returns (lowered, compiled, info dict).  ``tp="explicit"`` routes the
    decoder family through the shard_map partial-sum stack
    (model.decoder_stack_tp) instead of implicit GSPMD sharding;
    ``sp=True`` additionally shards inter-block activations over the model
    axis (sequence-parallel LN regions; full-sequence train/prefill shapes
    — decode shapes are skipped)."""
    shape_cfg = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    cfg = SP.dryrun_overrides(cfg, shape_cfg)
    if connection:
        cfg = cfg.replace(connection=connection)
    if extra_overrides:
        cfg = cfg.replace(**extra_overrides)
    ok, why = shape_applicable(cfg, shape_cfg)
    if not ok:
        return None, None, {"skipped": why}
    if sp and shape_cfg.mode == "decode":
        return None, None, {"skipped": "sequence-parallel LN regions are a "
                                       "full-sequence (train/prefill) "
                                       "layout; decode ticks are 1-token"}

    fax = _fsdp_axes(cfg, mesh) if fsdp else ()
    plan = ExecutionPlan.from_mesh(mesh, tp=tp, sp=sp,
                                   model_axis=MX.MODEL).validate(cfg)

    with mesh:
        if shape_cfg.mode == "train":
            nmb = SP.num_microbatches(cfg, shape_cfg, mesh)
            state_sds, batch_sds = SP.train_input_specs(
                cfg, shape_cfg, mesh, fax)
            gshard = jax.tree.map(lambda s: s.sharding, state_sds["params"])
            step = tstep.make_train_step(cfg, SP.opt_cfg_for(cfg),
                                         plan, nmb,
                                         grad_shardings=gshard)
            out_sh = jax.tree.map(lambda s: s.sharding, state_sds)
            lowered = jax.jit(
                step, out_shardings=(out_sh, None)).lower(state_sds, batch_sds)
        else:
            # prefill lowers the forward pass; decode lowers serve_step
            if shape_cfg.mode == "prefill":
                from repro.models import model as M
                pre_plan = plan.with_phase(Phase.PREFILL)

                def prefill(params, batch):
                    logits, aux, _ = M.forward(params, cfg, batch, pre_plan)
                    return logits

                params_sds, _, _, _ = SP.decode_input_specs(
                    cfg, shape_cfg, mesh, fax)
                batch_sds = SP.batch_struct(cfg, shape_cfg, mesh)
                lowered = jax.jit(prefill).lower(params_sds, batch_sds)
            else:
                serve = make_serve_step(cfg, plan)
                params_sds, cache_sds, tok, pos = SP.decode_input_specs(
                    cfg, shape_cfg, mesh, fax)
                cache_sh = jax.tree.map(lambda s: s.sharding, cache_sds)
                lowered = jax.jit(
                    serve, out_shardings=(None, None, cache_sh)).lower(
                    params_sds, cache_sds, tok, pos)
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax<=0.4.x: one dict per device
        cost = cost[0] if cost else {}
    info = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "connection": cfg.connection, "fsdp": bool(fax),
        "tp": tp, "sp": bool(sp),
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {"flops": cost.get("flops"),
                 "bytes": cost.get("bytes accessed")},
    }
    return lowered, compiled, info


def run_one(arch, shape_name, mesh_kind, out_dir=None, connection=None,
            fsdp=True, save_hlo=True, extra_overrides=None, tag_suffix="",
            tp="gspmd", sp=False):
    mesh = MX.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        lowered, compiled, info = lower_pair(arch, shape_name, mesh,
                                             connection=connection, fsdp=fsdp,
                                             extra_overrides=extra_overrides,
                                             tp=tp, sp=sp)
    except Exception as e:  # noqa
        info = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}
        lowered = compiled = None
    info["mesh_kind"] = mesh_kind
    if out_dir and compiled is not None:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_kind}"
        if connection:
            tag += f"_{connection}"
        if tag_suffix:
            tag += f"_{tag_suffix}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(info, f, indent=1)
        if save_hlo:
            with open(os.path.join(out_dir, tag + ".hlo"), "w") as f:
                f.write(compiled.as_text())
    return info, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--connection", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tp", default="gspmd", choices=["gspmd", "explicit"],
                    help="explicit = shard_map partial-sum TP stack "
                         "(decoder family, train shapes)")
    ap.add_argument("--sp", action="store_true",
                    help="with --tp explicit: sequence-parallel LN regions "
                         "(activations sharded over the model axis; "
                         "reduce-scatter/all-gather instead of all-reduce)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    help="config override key=value (repeatable), e.g. "
                         "--set attn_shard=sequence --set route_groups=16")
    args = ap.parse_args()

    if args.sp and args.tp != "explicit":
        ap.error("--sp requires --tp explicit (sequence-parallel LN "
                 "regions live inside the explicit partial-sum shard_map "
                 "stack)")

    overrides = {}
    for kv in args.overrides:
        k, v = kv.split("=", 1)
        try:
            import ast
            v = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            pass
        overrides[k] = v

    archs = [a for a in ARCH_IDS if not a.startswith("gpt2")] \
        if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                t0 = time.time()
                info, compiled = run_one(arch, shape, mk, args.out,
                                         connection=args.connection,
                                         fsdp=not args.no_fsdp,
                                         save_hlo=not args.no_hlo,
                                         extra_overrides=overrides or None,
                                         tag_suffix="_".join(
                                             f"{k}-{v}" for k, v in
                                             overrides.items())[:40],
                                         tp=args.tp, sp=args.sp)
                if "skipped" in info:
                    print(f"SKIP  {arch:24s} {shape:12s} {mk}: "
                          f"{info['skipped']}", flush=True)
                elif "error" in info:
                    print(f"FAIL  {arch:24s} {shape:12s} {mk}: "
                          f"{info['error']}", flush=True)
                else:
                    mem = info["memory"]
                    per_dev = (mem["argument_bytes"] or 0) / 2**30
                    print(f"OK    {arch:24s} {shape:12s} {mk} "
                          f"compile={info['compile_s']}s "
                          f"args/dev={per_dev:.2f}GiB "
                          f"temp/dev={(mem['temp_bytes'] or 0)/2**30:.2f}GiB "
                          f"flops={info['cost']['flops']:.3g}",
                          flush=True)
                if compiled is not None:
                    del compiled


if __name__ == "__main__":
    main()
