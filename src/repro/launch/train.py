"""Training launcher.

Local (this container, 1 CPU device):
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --reduced --steps 100 --batch 8 --seq 128 --connection fal

Production (TPU pod / forced host devices): add --mesh single|multi to run
the real sharded train step (the same code path the dry-run lowers).
"""
import os
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-117m")
    ap.add_argument("--connection", default=None,
                    help="preln|parallel|fal|falplus (default: config's)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "onecycle", "wsd"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--tp", default="gspmd", choices=["gspmd", "explicit"],
                    help="with --mesh: explicit = shard_map partial-sum TP "
                         "stack (the paper's per-block collective structure)")
    ap.add_argument("--sp", action="store_true",
                    help="with --tp explicit: Megatron-SP sequence-parallel "
                         "LN regions — inter-block activations sharded over "
                         "the model axis, reduce-scatter/all-gather pairs "
                         "instead of all-reduces (1/tp the reduce bytes)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.mesh:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count=512")

    import jax
    from repro.configs.base import get_config
    from repro.core.plan import ExecutionPlan
    from repro.launch import mesh as MX
    from repro.train import trainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.connection:
        cfg = cfg.replace(connection=args.connection)

    in_shardings = None
    if args.tp == "explicit" and not args.mesh:
        raise ValueError("--tp explicit requires --mesh (the explicit-TP "
                         "stack shards over the production mesh)")
    if args.sp and (args.tp != "explicit" or not args.mesh):
        raise ValueError("--sp requires --mesh and --tp explicit "
                         "(sequence-parallel LN regions live inside the "
                         "explicit partial-sum shard_map stack)")
    if args.mesh:
        mesh = MX.make_production_mesh(multi_pod=(args.mesh == "multi"))
        plan = ExecutionPlan.from_mesh(mesh, tp=args.tp, sp=args.sp,
                                       model_axis=MX.MODEL)
    else:
        plan = ExecutionPlan.single_device()
    plan.validate(cfg)   # loud errors before any tracing

    print(f"training {cfg.arch_id} connection={cfg.connection} "
          f"layers={cfg.n_layers} d={cfg.d_model} tp={plan.tp.value} "
          f"sp={plan.sequence_parallel}", flush=True)
    state, hist = trainer.train(
        cfg, steps=args.steps, batch=args.batch, seq_len=args.seq,
        lr=args.lr, seed=args.seed, plan=plan,
        num_microbatches=args.microbatches, schedule=args.schedule,
        ckpt_dir=args.ckpt)
    print(f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
