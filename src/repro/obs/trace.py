"""Low-overhead span/event tracer exporting Chrome trace-event JSON.

The exported file loads directly in Perfetto (ui.perfetto.dev) or
chrome://tracing: a ``{"traceEvents": [...]}`` object whose events follow
the Trace Event Format — ``X`` complete events for spans, ``i`` instants
for lifecycle transitions, ``b``/``e`` async pairs for per-request
lifecycle spans and ``C`` counter samples.

Design constraints (the serving hot loop calls this every tick):

* off-by-default — a disabled tracer's ``span()`` returns a shared
  no-op context manager and records nothing;
* monotonic clocks — timestamps come from ``time.perf_counter_ns``
  relative to the tracer's epoch, never wall clocks;
* no I/O until ``write()``/``export()`` — events accumulate in a list.

``span(..., annotate=True)`` additionally enters
``jax.profiler.TraceAnnotation`` so spans emitted around jitted dispatches
line up with XLA device traces when ``jax.profiler`` captures are taken.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List


class _NullContext:
    """Reusable no-op context manager (cheaper than nullcontext per call)."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullContext()


class _Span:
    """Context manager recording one ``X`` complete event on exit."""
    __slots__ = ("tracer", "name", "cat", "tid", "args", "t0", "_ann")

    def __init__(self, tracer, name, cat, tid, args, annotate):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args
        self._ann = None
        if annotate:
            import jax
            self._ann = jax.profiler.TraceAnnotation(name)

    def __enter__(self):
        if self._ann is not None:
            self._ann.__enter__()
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self.t0
        if self._ann is not None:
            self._ann.__exit__(*exc)
        t = self.tracer
        ev = {"name": self.name, "cat": self.cat, "ph": "X",
              "pid": t.pid, "tid": self.tid,
              "ts": (self.t0 - t.epoch_ns) / 1e3, "dur": dur / 1e3}
        if self.args:
            ev["args"] = self.args
        t._events.append(ev)
        return False


class Tracer:
    """Span/event collector; ``enabled=False`` (default) records nothing."""

    def __init__(self, enabled: bool = True, pid: int = 0,
                 process_name: str = "repro-engine"):
        self.enabled = enabled
        self.pid = pid
        self.process_name = process_name
        self.epoch_ns = time.perf_counter_ns()
        self._events: List[Dict[str, Any]] = []

    # ------------------------------------------------------------- emit ----
    def _ts(self) -> float:
        return (time.perf_counter_ns() - self.epoch_ns) / 1e3

    def span(self, name: str, cat: str = "engine", tid: int = 0,
             annotate: bool = False, **args):
        """Context manager timing a span; ``annotate=True`` nests a
        ``jax.profiler.TraceAnnotation`` so XLA profiles align."""
        if not self.enabled:
            return _NULL_CTX
        return _Span(self, name, cat, tid, args or None, annotate)

    def instant(self, name: str, cat: str = "lifecycle", tid: int = 0,
                **args):
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "pid": self.pid, "tid": tid, "ts": self._ts()}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def begin_async(self, name: str, aid: int, cat: str = "request", **args):
        """Open an async span (rendered as a track-spanning bar keyed by
        ``aid`` — one per request in the engine's lifecycle trace)."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "b", "id": aid,
              "pid": self.pid, "tid": 0, "ts": self._ts()}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def end_async(self, name: str, aid: int, cat: str = "request", **args):
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "e", "id": aid,
              "pid": self.pid, "tid": 0, "ts": self._ts()}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def counter(self, name: str, value: float, cat: str = "metrics"):
        if not self.enabled:
            return
        self._events.append(
            {"name": name, "cat": cat, "ph": "C", "pid": self.pid, "tid": 0,
             "ts": self._ts(), "args": {name: value}})

    # ----------------------------------------------------------- export ----
    @property
    def events(self) -> List[Dict[str, Any]]:
        return self._events

    def clear(self):
        self._events.clear()
        self.epoch_ns = time.perf_counter_ns()

    def export(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        meta = [{"name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
                 "ts": 0, "args": {"name": self.process_name}}]
        return {"traceEvents": meta + self._events,
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.export(), f)
        return path


#: shared disabled tracer — the engines' default, so the untraced hot loop
#: pays one attribute load + one no-op context per span site
NULL_TRACER = Tracer(enabled=False)


def validate_chrome_trace(obj: dict) -> int:
    """Schema check for an exported trace (CI gate + tests): returns the
    event count, raising ``ValueError`` on any malformed event."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    for ev in events:
        if not isinstance(ev, dict):
            raise ValueError(f"event is not an object: {ev!r}")
        for key in ("name", "ph", "pid", "tid", "ts"):
            if key not in ev:
                raise ValueError(f"event missing '{key}': {ev!r}")
        if ev["ph"] not in ("X", "i", "b", "e", "C", "M"):
            raise ValueError(f"unknown phase {ev['ph']!r}: {ev!r}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"complete event missing 'dur': {ev!r}")
        if ev["ph"] in ("b", "e") and "id" not in ev:
            raise ValueError(f"async event missing 'id': {ev!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"bad ts: {ev!r}")
    return len(events)
