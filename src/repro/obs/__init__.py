"""Engine-wide observability: request-lifecycle tracing, a metrics
registry, and runtime kernel-dispatch telemetry.

Three pieces, all off-by-default and cheap when off:

* ``obs.trace``   — a low-overhead span/event tracer (monotonic clocks,
  context-manager API) exporting Chrome trace-event JSON loadable in
  Perfetto.  ``PagedEngine`` emits per-tick spans and per-request
  lifecycle events (QUEUED -> ADMITTED [-> PREFIX_HIT] -> PREFILL ->
  DECODE -> PREEMPTED/requeued -> FINISHED, plus COW / PREFIX_PARKED /
  PREFIX_EVICT instants from the prefix-sharing subsystem and
  SPEC_ROLLBACK instants when speculative-decode rejection rewinds page
  growth); engine
  dispatches are additionally
  wrapped in ``jax.profiler.TraceAnnotation`` so XLA device profiles line
  up with the engine spans.
* ``obs.metrics`` — counters / gauges / log-bucket histograms with
  percentile summaries, JSON export (merged into ``PagedEngine.stats()``)
  and Prometheus text-format export for scrape-based deployments.
* ``obs.runmeta`` — run-metadata stamping (git sha, jax/device versions)
  for every BENCH_*.json the benchmark harness writes.

Kernel-dispatch telemetry lives in ``kernels.ops``: every dispatcher
records which path (``fused-tpu`` vs ``cpu-fallback``) it lowered per call
site into the default registry, so benchmark JSONs carry MEASURED dispatch
paths instead of a bench-side guess.

Metric-name reference
=====================

======================================  =========  =======  ==========================================
name                                    type       unit     emitting site
======================================  =========  =======  ==========================================
engine_ticks_total                      counter    ticks    serve/scheduler.py  PagedEngine.step
engine_dispatches_total                 counter    calls    serve/scheduler.py  PagedEngine._run_packed
engine_packed_calls_total               counter    calls    serve/scheduler.py  PagedEngine._step_packed
engine_prefill_tokens_total             counter    tokens   serve/scheduler.py  PagedEngine._run_packed
engine_decode_tokens_total              counter    tokens   serve/scheduler.py  PagedEngine._run_packed
engine_preemptions_total                counter    events   serve/scheduler.py  PagedEngine._preempt
engine_rejected_total                   counter    events   serve/scheduler.py  PagedEngine._reject
engine_admitted_total                   counter    events   serve/scheduler.py  PagedEngine._admit
engine_finished_total                   counter    events   serve/scheduler.py  PagedEngine._finish
engine_occupancy                        histogram  ratio    serve/scheduler.py  PagedEngine._run_packed
engine_tokens_per_dispatch              histogram  tokens   serve/scheduler.py  PagedEngine._run_packed
engine_padding_fraction                 histogram  ratio    serve/scheduler.py  PagedEngine._run_packed
engine_page_utilization                 histogram  ratio    serve/scheduler.py  PagedEngine.step
engine_queue_wait_ticks                 histogram  ticks    serve/scheduler.py  PagedEngine._admit
engine_ttft_ms                          histogram  ms       serve/scheduler.py  PagedEngine._run_packed
engine_ttft_ticks                       histogram  ticks    serve/scheduler.py  PagedEngine._run_packed
engine_inter_token_ms                   histogram  ms       serve/scheduler.py  PagedEngine._run_packed
engine_request_latency_ticks            histogram  ticks    serve/scheduler.py  PagedEngine._finish
engine_dispatch_ms                      histogram  ms       serve/scheduler.py  PagedEngine._run_packed
engine_cow_copies_total                 counter    pages    serve/scheduler.py  PagedEngine._ensure
engine_a1_sig_seeded_total              counter    events   serve/scheduler.py  PagedEngine._admit
engine_ttft_hit_ms                      histogram  ms       serve/scheduler.py  PagedEngine._run_packed
engine_ttft_cold_ms                     histogram  ms       serve/scheduler.py  PagedEngine._run_packed
engine_ttft_hit_ticks                   histogram  ticks    serve/scheduler.py  PagedEngine._run_packed
engine_ttft_cold_ticks                  histogram  ticks    serve/scheduler.py  PagedEngine._run_packed
engine_spec_accepted_total              counter    tokens   serve/scheduler.py  PagedEngine._consume_spec_lane
engine_spec_rejected_total              counter    tokens   serve/scheduler.py  PagedEngine._consume_spec_lane
engine_spec_accepted_len                histogram  tokens   serve/scheduler.py  PagedEngine._consume_spec_lane
pages_in_use                            gauge      pages    serve/paged_cache.py PageAllocator
pages_shared                            gauge      pages    serve/paged_cache.py PageAllocator
engine_kv_bytes_in_use                  gauge      bytes    serve/paged_cache.py PageAllocator
pages_alloc_total                       counter    pages    serve/paged_cache.py PageAllocator.alloc
pages_free_total                        counter    pages    serve/paged_cache.py PageAllocator.free
pages_shared_total                      counter    pages    serve/paged_cache.py PageAllocator.share
prefix_hits_total                       counter    admissions serve/prefix_cache.py PrefixCache.note_admission
prefix_misses_total                     counter    admissions serve/prefix_cache.py PrefixCache.note_admission
prefix_hit_tokens                       histogram  tokens   serve/prefix_cache.py PrefixCache.note_admission
prefix_inserted_pages_total             counter    pages    serve/prefix_cache.py PrefixCache.insert
prefix_evicted_pages_total              counter    pages    serve/prefix_cache.py PrefixCache.evict
prefix_cached_pages                     gauge      pages    serve/prefix_cache.py PrefixCache
batcher_ticks_total                     counter    ticks    serve/decode.py     ContinuousBatcher.step
batcher_dispatches_total                counter    calls    serve/decode.py     ContinuousBatcher.step
batcher_occupancy                       histogram  ratio    serve/decode.py     ContinuousBatcher.step
kernel_dispatch_total.<site>.<path>     counter    traces   kernels/ops.py      every dispatcher
kernel_dispatch_total.<site>.<kv>.<path> counter   traces   kernels/ops.py      paged dispatchers, quantized KV (<kv> = int8|fp8)
train_steps_total                       counter    steps    train/trainer.py    train()
train_tokens_total                      counter    tokens   train/trainer.py    train()
train_step_ms                           histogram  ms       train/trainer.py    train()
train_<metric>                          gauge      —        train/trainer.py    every logged step scalar
train_eval_ppl                          gauge      ppl      train/trainer.py    eval cadence
======================================  =========  =======  ==========================================

``kernel_dispatch_total`` counts TRACES, not executed calls: the
dispatchers run under ``jax.jit``, so the per-site record fires when a
(site, shape) program is traced and the chosen path cannot change without
a re-trace — exactly the invariant the BENCH dispatch-path labels need.
"""
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
)
from repro.obs.runmeta import run_metadata  # noqa: F401
from repro.obs.trace import NULL_TRACER, Tracer  # noqa: F401
