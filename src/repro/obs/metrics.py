"""Metrics registry: counters, gauges and log-bucket histograms.

Replaces the engines' ad-hoc ``_occ``/``_util`` sample lists and the
benchmark-side percentile helpers with one typed store:

    reg = MetricsRegistry()
    reg.counter("engine_ticks_total", unit="ticks").inc()
    reg.histogram("engine_ttft_ms", unit="ms").record(12.3)
    reg.to_dict()          # JSON export (merged into PagedEngine.stats())
    reg.prometheus_text()  # text exposition for scrape-based deployments

Histograms are log-bucketed (growth factor 1.05, ~5% relative resolution)
with exact count/sum/min/max, so ``percentile()`` is within one bucket of
the numpy reference at any sample volume while storage stays O(buckets)
instead of O(samples).  The full metric-name reference table lives in the
``repro.obs`` package docstring.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Optional

_LOG_BASE = 1.05
_LN_BASE = math.log(_LOG_BASE)


class Counter:
    """Monotonic counter (resettable via the registry)."""
    __slots__ = ("name", "unit", "site", "value")

    def __init__(self, name: str, unit: str = "", site: str = ""):
        self.name, self.unit, self.site = name, unit, site
        self.value = 0

    def inc(self, n: int = 1):
        self.value += n

    def reset(self):
        self.value = 0

    def to_dict(self) -> dict:
        return {"type": "counter", "unit": self.unit, "value": self.value}


class Gauge:
    """Last-written value."""
    __slots__ = ("name", "unit", "site", "value")

    def __init__(self, name: str, unit: str = "", site: str = ""):
        self.name, self.unit, self.site = name, unit, site
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)

    def reset(self):
        self.value = 0.0

    def to_dict(self) -> dict:
        return {"type": "gauge", "unit": self.unit, "value": self.value}


class Histogram:
    """Log-bucket histogram with exact count/sum/min/max.

    Buckets hold counts of samples with ``base**(i-1) < v <= base**i``;
    non-positive samples land in a dedicated underflow bucket.  Percentiles
    interpolate inside the winning bucket, so the error vs a sorted-sample
    reference is bounded by the bucket width (~5% relative)."""
    __slots__ = ("name", "unit", "site", "count", "total", "min", "max",
                 "_buckets")

    def __init__(self, name: str, unit: str = "", site: str = ""):
        self.name, self.unit, self.site = name, unit, site
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets: Dict[int, int] = {}

    def record(self, v: float):
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        idx = -(2 ** 31) if v <= 0 else math.ceil(math.log(v) / _LN_BASE)
        self._buckets[idx] = self._buckets.get(idx, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """p in [0, 100]; 0.0 when empty.

        The extreme ranks are exact: p == 0 returns the recorded min and
        p == 100 the recorded max (count/min/max are tracked exactly, so
        neither needs a bucket walk — and a bucket walk would be wrong:
        rank 0 trivially satisfies ``seen >= rank`` at the FIRST bucket,
        which is the min's bucket only by accident).  Interior ranks
        interpolate at the winning bucket's midpoint, clamped to the
        extrema."""
        if not self.count:
            return 0.0
        if p <= 0.0:
            return self.min
        if p >= 100.0:
            return self.max
        rank = p / 100.0 * self.count
        seen = 0
        for idx in sorted(self._buckets):
            n = self._buckets[idx]
            seen += n
            if seen >= rank:
                if idx == -(2 ** 31):
                    # non-positive samples share one underflow bucket (no
                    # log midpoint exists): the first sample there is the
                    # recorded min; deeper ranks clamp to the bucket's
                    # upper edge (0) within the recorded extrema
                    if rank <= 1.0:
                        return self.min
                    return min(max(0.0, self.min), self.max)
                lo, hi = _LOG_BASE ** (idx - 1), _LOG_BASE ** idx
                # clamp the edge buckets to the exact extrema
                return min(max((lo + hi) / 2.0, self.min), self.max)
        return self.max

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}

    def reset(self):
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets.clear()

    def to_dict(self) -> dict:
        return {"type": "histogram", "unit": self.unit, **self.summary()}


class MetricsRegistry:
    """Get-or-create registry of named series.

    Thread-safe at the registration level (the engines are single-threaded
    per instance; registration can race when a trainer callback and an
    engine share the default registry)."""

    def __init__(self):
        self._series: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, unit: str, site: str):
        s = self._series.get(name)
        if s is None:
            with self._lock:
                s = self._series.get(name)
                if s is None:
                    s = cls(name, unit, site)
                    self._series[name] = s
        if not isinstance(s, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(s).__name__}, not {cls.__name__}")
        return s

    def counter(self, name: str, unit: str = "", site: str = "") -> Counter:
        return self._get(Counter, name, unit, site)

    def gauge(self, name: str, unit: str = "", site: str = "") -> Gauge:
        return self._get(Gauge, name, unit, site)

    def histogram(self, name: str, unit: str = "",
                  site: str = "") -> Histogram:
        return self._get(Histogram, name, unit, site)

    def get(self, name: str) -> Optional[object]:
        return self._series.get(name)

    def names(self):
        return sorted(self._series)

    def reset(self):
        """Zero every series (registration survives — reporting stays
        stable across benchmark warmup resets)."""
        for s in self._series.values():
            s.reset()

    def to_dict(self) -> dict:
        return {name: self._series[name].to_dict()
                for name in sorted(self._series)}

    def prometheus_text(self, prefix: str = "repro_") -> str:
        """Prometheus text exposition format (histograms as summaries)."""
        out = []
        for name in sorted(self._series):
            s = self._series[name]
            pname = prefix + name
            if isinstance(s, Counter):
                out.append(f"# TYPE {pname} counter")
                out.append(f"{pname} {s.value}")
            elif isinstance(s, Gauge):
                out.append(f"# TYPE {pname} gauge")
                out.append(f"{pname} {s.value}")
            else:
                out.append(f"# TYPE {pname} summary")
                for q in (0.5, 0.9, 0.99):
                    out.append(f'{pname}{{quantile="{q}"}} '
                               f"{s.percentile(q * 100)}")
                out.append(f"{pname}_sum {s.total}")
                out.append(f"{pname}_count {s.count}")
        return "\n".join(out) + "\n"


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """Process-wide registry — the kernel-dispatch telemetry sink
    (``kernels.ops``) and the fallback for engines built without one."""
    return _DEFAULT
