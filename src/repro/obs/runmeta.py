"""Run-metadata stamping for benchmark artifacts.

Every BENCH_*.json the harness writes carries a ``meta`` block identifying
WHAT produced the numbers: git sha, jax/jaxlib versions, device kind and
count, python version, and the runner-supplied timestamp.  Without it a
committed BENCH number is unfalsifiable — there is no way to tell a TPU
run from a CPU fallback or a stale artifact from a fresh one.
"""
from __future__ import annotations

import platform
import subprocess
import sys


def _git_sha(repo_dir: str = ".") -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"], cwd=repo_dir,
            capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            sha = out.stdout.strip()
            dirty = subprocess.run(
                ["git", "status", "--porcelain"], cwd=repo_dir,
                capture_output=True, text=True, timeout=10)
            if dirty.returncode == 0 and dirty.stdout.strip():
                sha += "-dirty"
            return sha
    except (OSError, subprocess.TimeoutExpired):
        pass
    return "unknown"


def run_metadata(timestamp: float = None, repo_dir: str = ".",
                 dispatch_paths: dict = None) -> dict:
    """Stamp for a benchmark run.  ``timestamp`` is passed in by the runner
    (scripts cannot self-date deterministically under replay harnesses);
    ``dispatch_paths`` is the runtime kernel-dispatch map from
    ``kernels.ops.dispatch_paths()`` when the suite exercised kernels."""
    import jax

    devices = jax.devices()
    meta = {
        "git_sha": _git_sha(repo_dir),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else "none",
        "device_count": len(devices),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
    }
    if timestamp is not None:
        meta["timestamp"] = timestamp
    if dispatch_paths is not None:
        meta["dispatch_paths"] = dict(dispatch_paths)
    return meta
