"""mamba2-370m [ssm]: SSD, attention-free [arXiv:2405.21060].
FAL is inapplicable (no MHA-MLP pair) — DESIGN.md §Arch-applicability."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-370m", family="ssm", source="arXiv:2405.21060",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50304, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    ssm_chunk=256, norm="rmsnorm", connection="preln", rope=False,
    max_seq=524288,
)
