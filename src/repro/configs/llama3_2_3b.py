"""llama3.2-3b [dense]: small llama3 [hf:meta-llama/Llama-3.2 family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3.2-3b", family="dense", source="hf:meta-llama/Llama-3.2-1B",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=128256, rope_theta=5e5, norm="rmsnorm", mlp="swiglu",
    connection="fal", max_seq=32768,
)
