"""minicpm-2b [dense]: llama-like, trained with WSD schedule
[arXiv:2404.06395]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm-2b", family="dense", source="arXiv:2404.06395",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122880, norm="rmsnorm", mlp="swiglu", connection="fal",
    max_seq=32768,
)
