"""deepseek-v3-671b [moe]: MLA + 1 shared + 256 routed top-8 + MTP
[arXiv:2412.19437]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b", family="moe", source="arXiv:2412.19437",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab=129280, n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=3, dense_d_ff=18432, mtp_depth=1,
    # node-limited routing (DeepSeek-V3 §3.4): groups aligned to the 16-way
    # expert-parallel shards, each token restricted to 4 groups — bounds the
    # dispatch all-to-all to 4 shard copies (EXPERIMENTS.md §Perf D3)
    route_groups=16, route_group_limit=4,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    norm="rmsnorm", mlp="swiglu", connection="fal", tie_embeddings=False,
    max_seq=524288,
)
