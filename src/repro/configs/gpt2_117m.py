"""GPT-2 117M: the paper's motivation-analysis model (Pre-LN, MHA, GELU,
learned positions)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gpt2-117m", family="dense", source="paper baseline (GPT-2)",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=50304, rope=False, learned_pos=True, norm="layernorm", mlp="gelu",
    connection="preln", max_seq=1024,
)
