"""gemma2-27b [dense]: local/global alternating attention + logit softcaps
[arXiv:2408.00118]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-27b", family="dense", source="arXiv:2408.00118",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000, sliding_window=4096,
    layer_pattern="local_global", attn_softcap=50.0, final_softcap=30.0,
    post_norms=True, embed_scale=True, norm="rmsnorm", mlp="geglu",
    connection="fal", max_seq=524288,
)
