"""whisper-small [audio]: enc-dec; mel/conv frontend STUBBED as frame
embeddings [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small", family="audio", source="arXiv:2212.04356",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51968, is_encoder_decoder=True, n_enc_frames=1500,
    rope=False, learned_pos=True, norm="layernorm", mlp="gelu",
    connection="fal", max_seq=32768,
)
