"""GPT-2 774M (36L): paper Table 1 baseline."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gpt2-774m", family="dense", source="paper Table 1",
    n_layers=36, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=50304, rope=False, learned_pos=True, norm="layernorm", mlp="gelu",
    connection="preln", max_seq=1024,
)
