"""llava-next-mistral-7b [vlm]: Mistral-7B backbone, anyres vision tiles
stubbed as precomputed patch embeddings [hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-mistral-7b", family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, n_image_tokens=2880,  # 5 anyres tiles x 576 patches
    norm="rmsnorm", mlp="swiglu", connection="fal", tie_embeddings=False,
    max_seq=32768,
)
