"""Model/config registry for the FAL reproduction framework.

Every assigned architecture gets a module in this package exporting
``CONFIG: ModelConfig``.  ``get_config(arch_id)`` resolves it; reduced smoke
variants come from ``ModelConfig.reduced()``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

ConnectionMode = str  # 'preln' | 'parallel' | 'fal' | 'falplus'

VALID_CONNECTIONS = ("preln", "parallel", "fal", "falplus",
                     "ablation1", "ablation2")  # ablations: paper Apdx D.1
VALID_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity ---------------------------------------------------------------
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""  # citation for the config numbers

    # trunk ------------------------------------------------------------------
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    head_dim: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 3072
    vocab: int = 50257
    max_seq: int = 8192

    # paper's contribution ----------------------------------------------------
    connection: ConnectionMode = "preln"

    # attention options --------------------------------------------------------
    rope: bool = True
    rope_theta: float = 10000.0
    learned_pos: bool = False           # gpt2/whisper style
    qk_norm: bool = False               # qwen3
    attn_softcap: float = 0.0           # gemma2 (50.0); 0 disables
    final_softcap: float = 0.0          # gemma2 (30.0)
    sliding_window: int = 0             # 0 = full attention
    layer_pattern: str = "uniform"      # uniform | local_global (gemma2)
    post_norms: bool = False            # gemma2 post-attn/post-ffn norms
    embed_scale: bool = False           # gemma2: multiply embeddings by sqrt(d)

    # norms / mlp ---------------------------------------------------------------
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    mlp: str = "swiglu"                 # swiglu | gelu | geglu
    tie_embeddings: bool = True

    # MoE -----------------------------------------------------------------------
    n_experts: int = 0                  # 0 = dense MLP
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                   # per-expert hidden (deepseek: 2048)
    first_dense_layers: int = 0         # deepseek: first 3 layers dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # group-limited routing (DeepSeek-V3 §: node-limited top-k): each token's
    # experts restricted to <= route_group_limit of route_groups expert
    # groups; with groups aligned to expert-parallel shards this bounds the
    # all-to-all duplication to route_group_limit copies instead of top_k
    # (EXPERIMENTS.md §Perf D3).  0 = off.
    route_groups: int = 0
    route_group_limit: int = 4
    dense_d_ff: int = 0                 # d_ff of the dense layers (deepseek 18432)

    # MLA (deepseek) --------------------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2) ------------------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # hybrid (zamba2) -------------------------------------------------------------
    attn_every: int = 0                 # shared attention block every N ssm layers
    shared_attn: bool = False           # weight-shared attention block

    # enc-dec (whisper) -------------------------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    n_enc_frames: int = 1500            # stubbed audio frame embeddings

    # vlm (llava) ----------------------------------------------------------------------
    n_image_tokens: int = 0             # stubbed patch embeddings (anyres tiles)

    # MTP (deepseek) ------------------------------------------------------------
    mtp_depth: int = 0                  # extra multi-token-prediction heads

    # numerics -------------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    attn_block_q: int = 512             # blockwise-attention tile sizes
    attn_block_k: int = 1024
    # beyond-paper sharding (EXPERIMENTS.md §Perf):
    #   'auto'     — GSPMD decides (baseline; with Hkv < model-size it picks
    #                contraction sharding and all-reduces the score matmuls)
    #   'sequence' — context-parallel attention via shard_map: q sharded on
    #                seq over `model`, K/V gathered, zero attention ARs
    attn_shard: str = "auto"

    def __post_init__(self):
        assert self.connection in VALID_CONNECTIONS, self.connection
        assert self.family in VALID_FAMILIES, self.family

    # -------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic / long-context-capable (see DESIGN.md skip matrix)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.sliding_window and self.layer_pattern == "local_global":
            return True  # gemma2
        if self.use_mla:
            return True  # deepseek MLA compressed KV
        return self.sliding_window > 0

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs have a decoder

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/features, tiny dims (<=512 d_model,
        2 layers, <=4 experts)."""
        kw = dict(
            n_layers=2 if self.family != "hybrid" else 4,
            d_model=min(self.d_model, 128),
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            max_seq=256,
            dtype="float32",
            param_dtype="float32",
            remat=False,
            attn_block_q=32,
            attn_block_k=64,
        )
        if self.n_experts:
            # capacity_factor = E makes C >= T*k (dropless): capacity drops
            # depend on the token count and would make prefill != decode in
            # the equivalence tests.
            kw.update(n_experts=4, top_k=2, moe_d_ff=64, capacity_factor=4.0,
                      route_groups=2 if self.route_groups else 0,
                      route_group_limit=1,
                      n_shared_experts=min(self.n_shared_experts, 1),
                      first_dense_layers=min(self.first_dense_layers, 1),
                      dense_d_ff=128 if self.dense_d_ff else 0)
        if self.use_mla:
            kw.update(q_lora_rank=48, kv_lora_rank=32, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
        if self.attn_every:
            kw.update(attn_every=2)
        if self.is_encoder_decoder:
            kw.update(n_enc_layers=2, n_enc_frames=16)
        if self.n_image_tokens:
            kw.update(n_image_tokens=16)
        if self.sliding_window:
            kw.update(sliding_window=64)
        if self.mtp_depth:
            kw.update(mtp_depth=1)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
ARCH_IDS = (
    "zamba2-1.2b",
    "llava-next-mistral-7b",
    "qwen3-4b",
    "mamba2-370m",
    "deepseek-v3-671b",
    "minicpm-2b",
    "qwen3-moe-30b-a3b",
    "whisper-small",
    "gemma2-27b",
    "llama3.2-3b",
    # paper's own model family (reproduction baselines)
    "gpt2-117m",
    "gpt2-774m",
    "gpt2-1.5b",
)


def get_config(arch_id: str, **overrides) -> ModelConfig:
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ModelConfig = mod.CONFIG
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """DESIGN.md §Decode-shape skip matrix."""
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return False, ("pure full-attention arch: long_500k requires "
                       "sub-quadratic attention (DESIGN.md skip matrix)")
    if shape.name == "long_500k" and cfg.is_encoder_decoder:
        return False, "enc-dec (whisper): 500k decode out of family scope"
    return True, ""
