"""GPT-2 1.5B (48L): paper Table 1 baseline."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gpt2-1.5b", family="dense", source="paper Table 1",
    n_layers=48, d_model=1600, n_heads=25, n_kv_heads=25, d_ff=6400,
    vocab=50304, rope=False, learned_pos=True, norm="layernorm", mlp="gelu",
    connection="preln", max_seq=1024,
)
