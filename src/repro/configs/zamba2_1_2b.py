"""zamba2-1.2b [hybrid]: Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b", family="hybrid", source="arXiv:2411.15242",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    attn_every=6, shared_attn=True, norm="rmsnorm", mlp="swiglu",
    connection="fal", max_seq=524288,
)
