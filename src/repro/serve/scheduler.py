"""Continuous-batching scheduler over the paged KV cache.

Replaces the seed's fixed contiguous (B, max_seq) cache + one-token-per-tick
engine with:

  * admission control — a request enters a slot only when the page pool can
    cover its context (policy 'prompt': prompt + 1 token; 'full': prompt +
    max_new, no-preemption reservation);
  * MIXED ticks — the engine compiles exactly ONE jitted
    (slots, prefill_chunk) program (``make_paged_step``) and issues ONE
    dispatch per tick that serves lanes at ANY phase: prefilling lanes
    advance up to ``prefill_chunk`` prompt tokens while decoding lanes
    advance 1 sampled token in the SAME call (per-lane ``pos``/``n_valid``
    vectors mask the rest; the chunked block-table kernel
    ``kernels.ops.paged_chunk_attention`` serves the attention).  Decode
    lanes are never head-of-line blocked behind a prefill dispatch, and
    per-tick dispatch overhead is paid once;
  * per-request seeded sampling (serve/sampling.py) fused into the tick's
    dispatch;
  * preemption by page pressure — when a slot can't grow its block table,
    the youngest other active request is evicted: its pages are released and
    it is requeued (front).  On re-admission it re-prefills prompt +
    already-generated tokens; (seed, position)-derived sampling keys make
    the resumed continuation deterministic.  Re-prefill also rebuilds the
    slot's cached first-attention signal, so dual-branch dispatch stays
    consistent across preempt -> resume;
  * dual-branch decode (``EngineConfig.dual_branch``) — under fal/parallel
    connections the steady-state blocks issue the MLP branch off the cached
    per-slot FAL signal concurrently with the paged attention gather
    (MHA||MLP, the paper's inference-side claim); bit-identical tokens.

The oldest active request can always claim pages from younger ones, so the
engine makes progress whenever any single request fits the pool; requests
that can never fit are rejected instead of deadlocking the queue.

Observability (``repro.obs``): the engine owns a ``MetricsRegistry`` —
TTFT, inter-token latency, queue wait, occupancy, page utilization and
preemptions are recorded as typed series and surfaced by ``stats()``
(p50/p99 summaries + the full registry dump under ``"metrics"``).  Pass a
``Tracer`` to additionally capture per-tick spans, per-dispatch spans
(wrapped in ``jax.profiler.TraceAnnotation`` so XLA device profiles line
up) and per-request lifecycle events (QUEUED -> ADMITTED -> PREFILL ->
DECODE -> PREEMPTED/requeued -> FINISHED) as Chrome trace-event JSON.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ExecutionPlan, Phase
from repro.models import model as M
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.serve import sampling as SP
from repro.serve.paged_cache import BlockTable, PageAllocator, pages_needed

_SITE = "serve/scheduler.py"


# --------------------------------------------------------------------------- #
# the engine's ONE jitted program
# --------------------------------------------------------------------------- #
def make_paged_step(cfg, plan=None):
    """Jitted paged tick: (params, cache, tokens (B,C), pos (B,),
    n_valid (B,), block_tables (B,T), temps, top_ks, top_ps, seeds,
    sample_pos) -> (last_logits (B,V), next_tokens (B,), new_cache).

    The engine consumes exactly one row of logits per lane, so the program
    runs the blocks to hidden states, gathers each lane's LAST VALID row
    and applies the LM head to the (B, 1, D) gather — 1/C of the tick's
    dominant matmul compared to a full (B, C, V) head.

    ``plan`` is a typed ``core.plan.ExecutionPlan`` — the only way to
    configure the dispatch; its phase is pinned to paged here.
    ``plan.dual_branch`` selects the MHA||MLP branch-parallel block for the
    steady-state layers (fal/parallel-family connections; validated),
    overlapping each block's paged KV gather with its FFN off the cached
    per-slot first-attention signal.  The returned callable is
    phase-agnostic per LANE: lane b advances ``n_valid[b]`` tokens from its
    own position ``pos[b]`` — a mixed tick calls it once at C ==
    prefill_chunk with prefilling lanes at n_valid up to C and decoding
    lanes at n_valid == 1 (ONE trace, ONE dispatch per tick).  Sampling is
    fused into the program (no extra dispatch) and the cache buffers are
    donated, so page pools update in place instead of being copied every
    tick.
    """
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.PAGED)
    plan.validate(cfg)

    def step(params, cache, tokens, pos, n_valid, block_tables,
             temps, top_ks, top_ps, seeds, sample_pos):
        batch = {"tokens": tokens, "pos": pos, "n_valid": n_valid,
                 "block_tables": block_tables}
        hidden, new_cache = M.paged_decode_step(params, cfg, batch, cache,
                                                plan, want="hidden")
        h_last = last_valid_logits(hidden, n_valid)            # (B, D)
        logits = M.lm_head(params, cfg, h_last[:, None])[:, 0]  # (B, V)
        nxt = jax.vmap(SP.sample_one)(logits, temps, top_ks, top_ps,
                                      seeds, sample_pos)
        return logits, nxt, new_cache

    return jax.jit(step, donate_argnums=(1,))


def last_valid_logits(logits, n_valid):
    """(B, C, *), (B,) -> (B, *): each request's trailing-axis row at its
    last valid chunk lane (lane 0 for requests that sat out the tick).
    Shape-generic over the trailing axis — the engine's program applies it
    to hidden states before the LM head."""
    last = jnp.clip(n_valid - 1, 0, logits.shape[1] - 1)
    return jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]


def pack_chunks(token_lists, chunk, slots):
    """Host-side chunk packing: per-slot lists of pending context tokens ->
    (tokens (slots, chunk), n_valid (slots,)) numpy arrays.  Empty lists
    (idle slots) get n_valid == 0; decode-phase lanes carry exactly one
    token."""
    toks = np.zeros((slots, chunk), np.int32)
    n_valid = np.zeros((slots,), np.int32)
    for i, lst in enumerate(token_lists):
        n = min(len(lst), chunk)
        toks[i, :n] = lst[:n]
        n_valid[i] = n
    return toks, n_valid


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray                 # (P,) int token ids
    max_new: int
    sampling: SP.SamplingParams = SP.SamplingParams()
    generated: list = dataclasses.field(default_factory=list)
    pos: int = 0                       # tokens of context written to cache
    done: bool = False
    truncated: bool = False            # hit the context cap / rejected
    preemptions: int = 0
    arrival: int = -1                  # submit order (preemption priority)
    submit_tick: int = -1
    finish_tick: int = -1
    # observability (wall clocks are time.perf_counter seconds)
    submit_time: float = 0.0
    queued_tick: int = -1              # last (re-)queue tick, for queue wait
    last_token_time: float = 0.0
    decoding: bool = False             # per-residency phase (reset on preempt)

    def known(self) -> list:
        """Context to teacher-force: prompt + everything sampled so far."""
        return list(self.prompt) + self.generated


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Paged-engine knobs (see ROADMAP.md 'Serving')."""
    page_size: int = 16
    num_pages: int = 64                # pool size incl. scratch page 0
    slots: int = 4                     # concurrent batch lanes
    prefill_chunk: int = 16            # tokens per prefill dispatch
    max_seq: int = 256                 # per-request context cap
    admission: str = "prompt"          # 'prompt' | 'full'
    cache_dtype: str = "float32"
    # MHA||MLP branch-parallel decode dispatch off the cached per-slot FAL
    # signal (plan.dual_branch; fal/parallel-family connections only —
    # ExecutionPlan.validate rejects the rest).  Logits are bit-identical
    # to sequential decode on the CPU dispatch path (the fused TPU kernel
    # is tolerance-close); the win is overlap of the paged KV gather with
    # the FFN matmuls.
    dual_branch: bool = False


class PagedEngine:
    """Slot-based continuous batching over paged KV (decoder family).

    ``metrics``: a ``repro.obs.MetricsRegistry`` (one is created per engine
    when omitted — benchmarks driving several engines keep their series
    separate).  ``tracer``: a ``repro.obs.Tracer``; the default NULL tracer
    records nothing and costs one no-op context per span site."""

    def __init__(self, cfg, params, engine_cfg: EngineConfig = EngineConfig(),
                 plan=None, metrics: Optional[MetricsRegistry] = None,
                 tracer=None):
        if cfg.family not in M.PAGED_FAMILIES:
            raise NotImplementedError(cfg.family)
        if cfg.n_image_tokens:
            # model.paged_decode_step supports image_embeds, but the engine's
            # request/step plumbing is text-only — refuse rather than serve
            # image prefixes as text tokens (silently wrong logits)
            raise NotImplementedError(
                "PagedEngine serves text-only requests; vlm image prefixes "
                "need image_embeds plumbed through ServeRequest")
        assert engine_cfg.admission in ("prompt", "full"), engine_cfg.admission
        self.cfg, self.params, self.ecfg = cfg, params, engine_cfg
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # the engine stores a typed plan, not a context dict; every jitted
        # dispatch it compiles runs under this plan with phase=paged
        self.plan = ExecutionPlan.resolve(plan).with_phase(Phase.PAGED)
        if engine_cfg.dual_branch:
            self.plan = self.plan.with_dual_branch()
        self.plan.validate(cfg)
        self.max_blocks = pages_needed(engine_cfg.max_seq,
                                       engine_cfg.page_size)
        self.cache = M.init_paged_cache(
            cfg, engine_cfg.num_pages, engine_cfg.page_size,
            engine_cfg.slots, engine_cfg.cache_dtype)
        self.step_fn = make_paged_step(cfg, self.plan)
        self.allocator = PageAllocator(engine_cfg.num_pages,
                                       engine_cfg.page_size,
                                       metrics=self.metrics)
        self.tables = [BlockTable(self.allocator, self.max_blocks)
                       for _ in range(engine_cfg.slots)]
        self.slots: List[Optional[ServeRequest]] = [None] * engine_cfg.slots
        self.queue: List[ServeRequest] = []
        self.finished: List[ServeRequest] = []
        self.ticks = 0
        self.mixed_calls = 0
        self.dispatches = 0
        self.dispatch_ticks = 0        # ticks that issued >= 1 dispatch
        self._arrival = 0
        # registered up front so reset()/export enumerate a stable set
        self._c_ticks = self.metrics.counter(
            "engine_ticks_total", unit="ticks", site=_SITE)
        self._c_dispatches = self.metrics.counter(
            "engine_dispatches_total", unit="calls", site=_SITE)
        self._c_mixed = self.metrics.counter(
            "engine_mixed_calls_total", unit="calls", site=_SITE)
        self._c_prefill_toks = self.metrics.counter(
            "engine_prefill_tokens_total", unit="tokens", site=_SITE)
        self._c_decode_toks = self.metrics.counter(
            "engine_decode_tokens_total", unit="tokens", site=_SITE)
        self._c_preempt = self.metrics.counter(
            "engine_preemptions_total", unit="events", site=_SITE)
        self._c_rejected = self.metrics.counter(
            "engine_rejected_total", unit="events", site=_SITE)
        self._c_admitted = self.metrics.counter(
            "engine_admitted_total", unit="events", site=_SITE)
        self._c_finished = self.metrics.counter(
            "engine_finished_total", unit="events", site=_SITE)
        self._h_occ = self.metrics.histogram(
            "engine_occupancy", unit="ratio", site=_SITE)
        self._h_util = self.metrics.histogram(
            "engine_page_utilization", unit="ratio", site=_SITE)
        self._h_queue_wait = self.metrics.histogram(
            "engine_queue_wait_ticks", unit="ticks", site=_SITE)
        self._h_ttft_ms = self.metrics.histogram(
            "engine_ttft_ms", unit="ms", site=_SITE)
        self._h_ttft_ticks = self.metrics.histogram(
            "engine_ttft_ticks", unit="ticks", site=_SITE)
        self._h_itl_ms = self.metrics.histogram(
            "engine_inter_token_ms", unit="ms", site=_SITE)
        self._h_req_ticks = self.metrics.histogram(
            "engine_request_latency_ticks", unit="ticks", site=_SITE)
        self._h_dispatch_ms = self.metrics.histogram(
            "engine_dispatch_ms", unit="ms", site=_SITE)

    # ------------------------------------------------------------------ #
    def submit(self, req: ServeRequest):
        req.arrival = self._arrival
        self._arrival += 1
        req.submit_tick = self.ticks
        req.queued_tick = self.ticks
        req.submit_time = time.perf_counter()
        self.queue.append(req)
        self.tracer.begin_async("req", req.rid, prompt_len=len(req.prompt),
                                max_new=req.max_new)
        self.tracer.instant("QUEUED", rid=req.rid)

    def _admission_pages(self, r: ServeRequest) -> int:
        ctx = len(r.known())
        ahead = ctx + (r.max_new - len(r.generated)) \
            if self.ecfg.admission == "full" else ctx + 1
        return pages_needed(min(ahead, self.ecfg.max_seq),
                            self.ecfg.page_size)

    def _reject(self, r: ServeRequest):
        r.done = r.truncated = True
        r.finish_tick = self.ticks
        self._c_rejected.inc()
        self.finished.append(r)
        self.tracer.instant("REJECTED", rid=r.rid)
        self.tracer.end_async("req", r.rid, outcome="rejected")

    def _admit(self):
        while self.queue:
            try:
                free = self.slots.index(None)
            except ValueError:
                return
            r = self.queue[0]
            ctx = len(r.known())
            need = self._admission_pages(r)
            # requests that can never complete are rejected instead of
            # deadlocking the queue (or livelocking the pool): the context
            # must fit max_seq with room to sample at least one token, and
            # its pages must fit the pool
            if (ctx + 1 > self.ecfg.max_seq
                    or need > min(self.max_blocks, self.allocator.capacity)):
                self.queue.pop(0)
                self._reject(r)
                continue
            if not self.allocator.can_alloc(need):
                return                       # FCFS: no head-of-line skipping
            self.queue.pop(0)
            r.pos = 0                        # (re-)prefill from scratch
            r.decoding = False
            self.slots[free] = r
            self._c_admitted.inc()
            self._h_queue_wait.record(self.ticks - r.queued_tick)
            self.tracer.instant("ADMITTED", rid=r.rid, slot=free,
                                wait_ticks=self.ticks - r.queued_tick)
            self.tracer.instant("PREFILL", rid=r.rid, slot=free,
                                context=ctx)
            if self.ecfg.admission == "full":
                # reservation policy: actually hold the worst-case pages now
                # so this request can never be preempted for page pressure
                ok = self.tables[free].ensure(
                    min(ctx + r.max_new - len(r.generated),
                        self.ecfg.max_seq))
                assert ok                    # can_alloc(need) just passed

    # ------------------------------------------------------------------ #
    def _preempt(self, i: int):
        r = self.slots[i]
        self.tables[i].release()
        r.pos = 0
        r.decoding = False
        r.preemptions += 1
        r.queued_tick = self.ticks
        self._c_preempt.inc()
        self.slots[i] = None
        self.queue.insert(0, r)              # front: resumes before new work
        self.tracer.instant("PREEMPTED", rid=r.rid, slot=i,
                            generated=len(r.generated))

    def _pick_victim(self, exclude: int) -> Optional[int]:
        cands = [i for i, r in enumerate(self.slots)
                 if r is not None and i != exclude]
        if not cands:
            return None
        return max(cands, key=lambda i: self.slots[i].arrival)  # youngest

    def _ensure(self, i: int, new_len: int) -> bool:
        """Grow slot i's block table to cover new_len tokens, evicting
        younger requests under page pressure.  False => slot i was itself
        preempted (or finished truncated) and is gone."""
        if pages_needed(new_len, self.ecfg.page_size) \
                > min(self.max_blocks, self.allocator.capacity):
            # infeasible no matter how many victims are evicted (would
            # livelock the while-loop below): finish truncated instead
            self._finish(i, truncated=True)
            return False
        while not self.tables[i].ensure(new_len):
            victim = self._pick_victim(exclude=i)
            if victim is None:
                self._preempt(i)
                return False
            self._preempt(victim)
        return True

    def _finish(self, i: int, truncated: bool = False):
        r = self.slots[i]
        r.done = True
        r.truncated = truncated
        r.finish_tick = self.ticks
        self.tables[i].release()
        self.slots[i] = None
        self.finished.append(r)
        self._c_finished.inc()
        self._h_req_ticks.record(r.finish_tick - r.submit_tick)
        self.tracer.instant("FINISHED", rid=r.rid, truncated=truncated,
                            generated=len(r.generated))
        self.tracer.end_async(
            "req", r.rid, outcome="truncated" if truncated else "finished")

    # ------------------------------------------------------------------ #
    def _run_call(self, ids: List[int], chunk: int):
        """One jitted engine call (forward + fused sampling) over the given
        participating slots; consume samples for every request whose context
        completed this call.  Lanes may be in DIFFERENT phases: each lane
        advances min(chunk, its remaining context) tokens."""
        B = self.ecfg.slots
        self.dispatches += 1
        self._c_dispatches.inc()
        self._h_occ.record(len(ids) / B)
        lists = [self.slots[i].known()[self.slots[i].pos:
                                       self.slots[i].pos + chunk]
                 if i in ids else [] for i in range(B)]
        toks, n_valid = pack_chunks(lists, chunk, B)
        pos = np.asarray([r.pos if r else 0 for r in self.slots], np.int32)
        bt = np.stack([t.as_row() for t in self.tables])
        temps = np.zeros((B,), np.float32)
        ks = np.zeros((B,), np.int32)
        ps = np.ones((B,), np.float32)
        seeds = np.zeros((B,), np.int32)
        poss = np.zeros((B,), np.int32)
        for i in ids:
            sp = self.slots[i].sampling
            temps[i], ks[i], ps[i] = sp.temperature, sp.top_k, sp.top_p
            seeds[i] = sp.seed
            # position of the would-be new token (== len(known()) exactly
            # when this call completes the request's context)
            poss[i] = self.slots[i].pos + int(n_valid[i])
        t0 = time.perf_counter()
        with self.tracer.span("engine.dispatch", annotate=True,
                              lanes=len(ids), chunk=chunk):
            _, nxt, self.cache = self.step_fn(
                self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
                jnp.asarray(n_valid), jnp.asarray(bt), jnp.asarray(temps),
                jnp.asarray(ks), jnp.asarray(ps), jnp.asarray(seeds),
                jnp.asarray(poss))
        self._h_dispatch_ms.record((time.perf_counter() - t0) * 1e3)
        for i in ids:
            r = self.slots[i]
            adv = int(n_valid[i])
            if len(r.known()) - r.pos == 1:
                self._c_decode_toks.inc(adv)
            else:
                self._c_prefill_toks.inc(adv)
            r.pos += adv
        need = [i for i in ids
                if self.slots[i].pos == len(self.slots[i].known())]
        if need:
            nxt_np = np.asarray(nxt)
            now = time.perf_counter()
            for i in need:
                r = self.slots[i]
                r.generated.append(int(nxt_np[i]))
                if len(r.generated) == 1:
                    self._h_ttft_ms.record((now - r.submit_time) * 1e3)
                    self._h_ttft_ticks.record(self.ticks - r.submit_tick)
                elif r.last_token_time:
                    self._h_itl_ms.record((now - r.last_token_time) * 1e3)
                r.last_token_time = now
                if not r.decoding:
                    r.decoding = True
                    self.tracer.instant("DECODE", rid=r.rid, slot=i,
                                        generated=len(r.generated))
                if len(r.generated) >= r.max_new:
                    self._finish(i)
                elif len(r.known()) >= self.ecfg.max_seq:
                    self._finish(i, truncated=True)

    # ------------------------------------------------------------------ #
    def step(self):
        """One engine tick: admit, then ONE mixed dispatch serving every
        active lane at its own phase."""
        self.ticks += 1
        self._c_ticks.inc()
        with self.tracer.span("engine.tick", tick=self.ticks):
            self._admit()
            d0 = self.dispatches
            self._step_mixed()
            if self.dispatches > d0:
                self.dispatch_ticks += 1
            self._h_util.record(self.allocator.stats()["utilization"])

    def _step_mixed(self):
        """ONE (slots, prefill_chunk) dispatch: prefilling lanes advance up
        to ``prefill_chunk`` positions, decoding lanes advance 1, in the
        same jitted call."""
        chunk = self.ecfg.prefill_chunk
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            feed = min(chunk, len(r.known()) - r.pos)
            if not self._ensure(i, r.pos + feed):
                pass                          # slot preempted/truncated
        ids = [i for i, r in enumerate(self.slots) if r is not None]
        if ids:
            self.mixed_calls += 1
            self._c_mixed.inc()
            self._run_call(ids, chunk)

    def run(self, max_ticks: Optional[int] = None) -> List[ServeRequest]:
        while any(s is not None for s in self.slots) or self.queue:
            if max_ticks is not None and self.ticks >= max_ticks:
                break
            self.step()
        return self.finished

    # ------------------------------------------------------------------ #
    def reset_stats(self):
        """Zero every counter/series (and drop buffered trace events) while
        keeping compiled programs, live requests and page state (benchmarks
        call this after warmup)."""
        self.ticks = 0
        self.mixed_calls = 0
        self.dispatches = self.dispatch_ticks = 0
        self.metrics.reset()
        self.tracer.clear()
        self.allocator.peak_in_use = self.allocator.in_use

    def stats(self) -> dict:
        frag = sum(self.tables[i].internal_fragmentation(self.slots[i].pos)
                   for i in range(self.ecfg.slots)
                   if self.slots[i] is not None)

        def pcts(h):
            return {"p50": h.percentile(50), "p99": h.percentile(99),
                    "mean": h.mean, "count": h.count}

        return {
            "ticks": self.ticks,
            "mixed_calls": self.mixed_calls,
            "dispatches": self.dispatches,
            "dispatch_ticks": self.dispatch_ticks,
            # the tentpole metric, over ticks that issued any dispatch (a
            # tick whose only lane was truncated/preempted mid-growth
            # legitimately issues none): EXACTLY 1.0 under mixed ticks
            "dispatches_per_tick":
                self.dispatches / max(self.dispatch_ticks, 1),
            # active lanes per dispatch / slots: mixed ticks keep every
            # occupied lane advancing in every dispatch
            "mean_occupancy": self._h_occ.mean,
            "prefill_tokens": self._c_prefill_toks.value,
            "decode_tokens": self._c_decode_toks.value,
            "preemptions": self._c_preempt.value,
            "rejected": self._c_rejected.value,
            "mean_page_utilization": self._h_util.mean,
            "internal_fragmentation": frag,
            "pages": self.allocator.stats(),
            # request-lifecycle latency summaries (the registry is the
            # source of truth; these are the headline cuts)
            "ttft_ms": pcts(self._h_ttft_ms),
            "ttft_ticks": pcts(self._h_ttft_ticks),
            "inter_token_ms": pcts(self._h_itl_ms),
            "queue_wait_ticks": pcts(self._h_queue_wait),
            "request_latency_ticks": pcts(self._h_req_ticks),
            "dispatch_ms": pcts(self._h_dispatch_ms),
            "metrics": self.metrics.to_dict(),
        }

    @property
    def preemptions(self) -> int:
        return self._c_preempt.value

    @property
    def rejected(self) -> int:
        return self._c_rejected.value
