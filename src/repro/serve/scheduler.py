"""Continuous-batching scheduler over the paged KV cache.

Replaces the seed's fixed contiguous (B, max_seq) cache + one-token-per-tick
engine with:

  * admission control — a request enters a slot only when the page pool can
    cover its context (policy 'prompt': prompt + 1 token; 'full': prompt +
    max_new, no-preemption reservation);
  * TOKEN-PACKED ticks — the engine compiles exactly ONE jitted flat
    ``(token_budget,)`` program (``make_packed_step``) and issues ONE
    dispatch per tick that serves lanes at ANY phase over one ragged token
    buffer: token t belongs to lane ``tok_slot[t]`` at logical position
    ``tok_pos[t]``.  A prefilling lane contributes up to ``prefill_chunk``
    tokens, a decoding lane exactly one, so tick FLOPs scale with LIVE
    tokens instead of the padded slots-by-chunk rectangle (the
    segment-aware kernel ``kernels.ops.paged_packed_attention`` serves the
    attention; the LM head runs only on each segment's last token).
    Decode lanes are packed FIRST and are therefore never head-of-line
    blocked behind a prefill burst; ``EngineConfig.max_prefill_tokens`` is
    the fairness knob that additionally caps prefill tokens per tick, and
    prefill grants walk the pending lanes in rotating round-robin order
    (start index = tick counter) so no lane starves under budget pressure.
    ``pack_tokens`` is the pure host-side packer (property-tested);
  * SELF-SPECULATIVE decoding (``EngineConfig.spec_tokens``) — FAL's
    signal redirection makes the first ``draft_blocks`` blocks a built-in
    draft model: each eligible decode lane proposes n-1 tokens via the
    early-exit forward and packs the whole n-token proposal as ONE
    segment, verified by the same full-depth packed dispatch (a segment
    of length n at positions pos..pos+n-1 — per-segment causality scores
    every proposal exactly as sequential decode would).  Draft, verify
    and sampling live inside the engine's ONE jitted program per tick;
    the host accepts the longest matching proposal prefix plus the bonus
    target and rewinds rejected page growth (``BlockTable.shrink`` —
    refcount-safe, shared prefix pages survive).  Exact-match acceptance
    keeps greedy AND seeded token streams bit-identical to
    non-speculative decode;
  * per-request seeded sampling (serve/sampling.py) fused into the tick's
    dispatch — the engine picks between the reference sampler and the
    bit-exact partial-top-k fast sampler host-side per tick
    (``sampling.fast_eligible``), keeping speculative ticks from paying
    two full-vocab sorts per (lane, proposal) sample;
  * preemption by page pressure — when a slot can't grow its block table,
    the youngest other active request is evicted: its pages are released and
    it is requeued (front).  On re-admission it re-prefills prompt +
    already-generated tokens; (seed, position)-derived sampling keys make
    the resumed continuation deterministic.  Re-prefill also rebuilds the
    slot's cached first-attention signal, so dual-branch dispatch stays
    consistent across preempt -> resume;
  * dual-branch decode (``EngineConfig.dual_branch``) — under fal/parallel
    connections the steady-state blocks issue the MLP branch off the cached
    per-slot FAL signal concurrently with the paged attention gather
    (MHA||MLP, the paper's inference-side claim); bit-identical tokens;
  * radix prefix caching (``EngineConfig.prefix_cache``) — finished
    requests park their page-aligned prefixes (and the FAL ``a1_sig`` at
    the prompt's last position) in ``serve/prefix_cache.py``; admission
    longest-prefix matches the prompt, maps the cached PHYSICAL pages into
    the new request's block table (refcounted by the allocator) and enters
    prefill at the divergence point — or decode immediately on a
    full-prompt hit, with ``cache["a1_sig"]`` seeded from the entry so the
    first tick pays no block-0 assemble for the prefix.  Writes into a
    shared page copy-on-write first (``model.copy_paged_pages`` device
    memcpy + block-table swap), so a hit request can never corrupt another
    sharer's history; preemption releases only the preempted request's
    REFERENCES (shared pages survive in the tree), and its re-prefill
    restarts at the still-cached prefix instead of token 0.

The oldest active request can always claim pages from younger ones, so the
engine makes progress whenever any single request fits the pool; requests
that can never fit are rejected instead of deadlocking the queue.  Under
page pressure the relief order is: evict refcount-free prefix-cache
entries first, then preempt the youngest other request, then self.

Observability (``repro.obs``): the engine owns a ``MetricsRegistry`` —
TTFT, inter-token latency, queue wait, occupancy, page utilization and
preemptions are recorded as typed series and surfaced by ``stats()``
(p50/p99 summaries + the full registry dump under ``"metrics"``).  Pass a
``Tracer`` to additionally capture per-tick spans, per-dispatch spans
(wrapped in ``jax.profiler.TraceAnnotation`` so XLA device profiles line
up) and per-request lifecycle events (QUEUED -> ADMITTED -> PREFILL ->
DECODE -> PREEMPTED/requeued -> FINISHED) as Chrome trace-event JSON.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ExecutionPlan, Phase
from repro.models import model as M
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.serve import sampling as SP
from repro.serve.paged_cache import BlockTable, PageAllocator, pages_needed
from repro.serve.prefix_cache import PrefixCache

_SITE = "serve/scheduler.py"


# --------------------------------------------------------------------------- #
# the engine's ONE jitted program
# --------------------------------------------------------------------------- #
def make_packed_step(cfg, plan=None, *, sampler=None):
    """Jitted packed tick: (params, cache, tokens (T,), tok_slot (T,),
    tok_pos (T,), block_tables (S,Tb), seg_last (S,), temps, top_ks,
    top_ps, seeds, sample_pos) -> (seg_logits (S,V), next_tokens (S,),
    new_cache).

    T is the engine's flat token budget: one ragged buffer where token t
    belongs to lane ``tok_slot[t]`` at logical position ``tok_pos[t]``
    (padding tokens carry tok_pos == -1 and never touch live state).  The
    engine consumes at most one logits row per lane, so the program runs
    the blocks to hidden states, gathers each SEGMENT's last token
    (``seg_last``, -1 for lanes sitting the tick out) and applies the LM
    head to the (S, 1, D) gather — 1/T of the tick's dominant matmul
    compared to a full (T, V) head.

    ``plan`` is a typed ``core.plan.ExecutionPlan`` — the only way to
    configure the dispatch; its phase is pinned to paged here.
    ``plan.dual_branch`` selects the MHA||MLP branch-parallel block for the
    steady-state layers (fal/parallel-family connections; validated).  The
    returned callable is phase-agnostic per SEGMENT: a prefilling lane's
    segment spans up to ``prefill_chunk`` tokens, a decoding lane's exactly
    one, in the SAME call (ONE trace, ONE dispatch per tick, FLOPs in live
    tokens).  Sampling is fused into the program (no extra dispatch) and
    the cache buffers are donated, so page pools update in place instead of
    being copied every tick.
    """
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.PAGED)
    plan.validate(cfg)
    samp = sampler if sampler is not None else SP.sample_one

    def step(params, cache, tokens, tok_slot, tok_pos, block_tables,
             seg_last, temps, top_ks, top_ps, seeds, sample_pos):
        batch = {"tokens": tokens, "tok_slot": tok_slot, "tok_pos": tok_pos,
                 "block_tables": block_tables, "seg_last": seg_last}
        hidden, new_cache = M.paged_decode_step(params, cfg, batch, cache,
                                                plan, want="hidden")
        # lanes sitting the tick out carry seg_last == -1: zero their
        # gathered row BEFORE the head (a clamped row-0 gather would run
        # the LM head + sampler on another lane's scratch state — NaN or
        # garbage there must never reach a sampled token) and return the
        # -1 sentinel instead of a sampled id
        active = seg_last >= 0
        h_seg = jnp.where(active[:, None],
                          hidden[0, jnp.maximum(seg_last, 0)], 0.0)  # (S, D)
        logits = M.lm_head(params, cfg, h_seg[:, None])[:, 0]    # (S, V)
        nxt = jax.vmap(samp)(logits, temps, top_ks, top_ps,
                             seeds, sample_pos)
        nxt = jnp.where(active, nxt, jnp.int32(-1))
        return logits, nxt, new_cache

    return jax.jit(step, donate_argnums=(1,))


def make_spec_step(cfg, plan=None, *, spec_tokens, draft_blocks,
                   sampler=None):
    """Jitted SELF-SPECULATIVE packed tick — still ONE dispatch per tick:
    (params, cache, tokens (T,), tok_slot (T,), tok_pos (T,),
    block_tables (S,Tb), seg_last (S,), spec_mask (S,), temps, top_ks,
    top_ps, seeds) -> (targets (S,n), fed (S,n), new_cache).

    Lanes with ``spec_mask`` set are decode lanes whose packed segment
    spans ``n == spec_tokens`` rows: the lane's pending token followed by
    n-1 device-filled placeholder rows at positions pos+1..pos+n-1.  The
    program runs, inside the SAME jit trace (so the engine's host-side
    dispatch counter still increments once per tick):

      1. DRAFT — n-1 unrolled early-exit iterations.  Iteration j embeds
         each spec lane's row ``seg_start + j`` as a flat (S,) packed
         batch (non-spec lanes ride as padding, tok_pos == -1), runs
         block 0 plus the first ``draft_blocks - 1`` stacked layers
         (``model.paged_spec_draft``; FAL's signal redirection makes the
         shallow prefix its own draft model), samples a proposal with the
         SAME replayable ``fold_in(seed, position)`` key the verify pass
         will use — identical keys + near-identical logits is what makes
         seeded-sampling proposals match their targets — and plants it in
         row ``seg_start + j + 1`` of the token buffer.
      2. VERIFY — the full-depth packed forward over the whole buffer in
         the tick's one ``paged_packed_attention``-backed program: a lane
         proposing n tokens is just a segment of length n, so per-segment
         causal masking scores every proposal against exactly the context
         a sequential decode would have seen.  The LM head runs on each
         segment's last n gathered rows (``model.lm_head_segment_tail``),
         and targets are sampled per position — ``targets[s, j]`` is the
         model's true next token after row ``rows[s, j]``.

    The host accepts the longest prefix of proposals that match their
    targets (exact-match speculative sampling: greedy AND seeded streams
    stay bit-identical to non-speculative decode) plus the bonus target
    after it, then rolls rejected growth back (``BlockTable.shrink``).
    Draft-layer K/V written for rejected rows is overwritten by verify /
    later re-feeds and stays causally invisible meanwhile.  Non-spec lanes
    (prefill segments, decode lanes near ``max_seq``, lanes awaiting
    their first token) consume ``targets[s, n-1]`` — the plain packed-tick
    sample.  Dead columns are zeroed before the head and return the -1
    sentinel (same NaN-containment contract as ``make_packed_step``).

    Note ``cache['a1_sig']`` is refreshed by the verify pass from each
    segment's LAST row — for a spec lane that position may be rejected.
    No packed-engine consumer reads it for spec lanes: the dual-branch
    packed path uses the tick's fresh per-token signal, and the
    prefix-cache artifact is captured on a lane's FIRST sampled token,
    which the engine always serves non-speculatively.
    """
    n = int(spec_tokens)
    assert n >= 2, "spec_tokens >= 2 (1 proposal minimum)"
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.PAGED)
    plan.validate(cfg)
    samp = sampler if sampler is not None else SP.sample_one

    def step(params, cache, tokens, tok_slot, tok_pos, block_tables,
             seg_last, spec_mask, temps, top_ks, top_ps, seeds):
        T = tokens.shape[0]
        S = seg_last.shape[0]
        seg_start = seg_last - (n - 1)
        lane = jnp.arange(S, dtype=jnp.int32)
        toks = tokens
        for j in range(n - 1):                       # ---- draft loop ----
            row = jnp.where(spec_mask, jnp.maximum(seg_start + j, 0), 0)
            dpos = jnp.where(spec_mask, tok_pos[row], -1)
            dbatch = {"tokens": jnp.where(spec_mask, toks[row], 0),
                      "tok_slot": lane, "tok_pos": dpos,
                      "block_tables": block_tables}
            dh, cache = M.paged_spec_draft(params, cfg, dbatch, cache,
                                           plan, draft_blocks=draft_blocks)
            h = jnp.where(spec_mask[:, None], dh[0], 0.0)        # (S, D)
            dlogits = M.lm_head(params, cfg, h[:, None])[:, 0]   # (S, V)
            dnext = jax.vmap(samp)(dlogits, temps, top_ks,
                                   top_ps, seeds, dpos + 1)
            wrow = jnp.where(spec_mask, seg_start + j + 1, T)
            toks = toks.at[wrow].set(dnext, mode="drop")
        # ---- verify: the tick's ONE full-depth packed dispatch --------
        batch = {"tokens": toks, "tok_slot": tok_slot, "tok_pos": tok_pos,
                 "block_tables": block_tables, "seg_last": seg_last}
        hidden, new_cache = M.paged_decode_step(params, cfg, batch, cache,
                                                plan, want="hidden")
        logits, rows = M.lm_head_segment_tail(params, cfg, hidden,
                                              seg_last, n)      # (S, n, V)
        col = jnp.arange(n, dtype=jnp.int32)[None, :]
        live = ((seg_last >= 0)[:, None] & (rows >= 0)
                & (spec_mask[:, None] | (col == n - 1)))
        rpos = tok_pos[jnp.maximum(rows, 0)]                     # (S, n)
        one = jax.vmap(samp, in_axes=(0, None, None, None, None, 0))
        tgt = jax.vmap(one)(logits, temps, top_ks, top_ps, seeds, rpos + 1)
        tgt = jnp.where(live, tgt, jnp.int32(-1))
        fed = jnp.where(live, toks[jnp.maximum(rows, 0)], jnp.int32(-1))
        return tgt, fed, new_cache

    return jax.jit(step, donate_argnums=(1,))


@dataclasses.dataclass(frozen=True)
class PackedTick:
    """One tick's flat token plan (host-side numpy, produced by
    ``pack_tokens``).  ``tokens[t]`` is fed to lane ``tok_slot[t]`` at
    logical position ``tok_pos[t]``; the padding tail carries tok_slot == 0
    and tok_pos == -1.  ``seg_last[i]`` is the flat index of slot i's last
    token (-1 when the slot sat the tick out) and ``n_taken[i]`` how many
    tokens slot i advances; ``n_live == n_taken.sum() <= len(tokens)``."""
    tokens: np.ndarray                 # (T,) int32
    tok_slot: np.ndarray               # (T,) int32
    tok_pos: np.ndarray                # (T,) int32
    seg_last: np.ndarray               # (S,) int32
    n_taken: np.ndarray                # (S,) int32
    n_live: int


def pack_tokens(token_lists, positions, decode_flags, budget,
                prefill_cap=0, rotate=0) -> PackedTick:
    """Pure host-side token packer: per-slot lists of pending context
    tokens (empty for idle slots) at per-slot ``positions`` -> a
    ``PackedTick`` over a flat ``(budget,)`` buffer.

    Packing order and fairness:
      * decode lanes (``decode_flags[i]``) are packed FIRST, in slot order,
        and take their WHOLE pending list — one token in plain decode, or
        the lane's n-token speculative proposal — never displaced by a
        prefill burst;
      * prefill lanes then split the remaining budget (optionally capped at
        ``prefill_cap`` tokens total, 0 = uncapped) in TRUE round-robin
        order: both grant rounds walk the pending prefill lanes starting at
        slot ``rotate % slots`` (the engine passes its tick counter), so
        under sustained budget pressure every pending lane leads the grant
        order at least once every ``slots`` ticks — even as lanes join and
        leave the pending set.  A first round grants one token per lane so
        every reached lane stays live; a second round fills lanes greedily
        in the same rotated order.  (A fixed slot-0 start — the
        pre-rotation behavior — starves high-numbered lanes for as long as
        the pressure lasts.)

    Each packed slot's tokens are contiguous with monotone positions
    ``positions[i] + arange(n_taken[i])``; the buffer lays segments out in
    slot order (decode lanes first) regardless of ``rotate``.  The caller
    guarantees the budget covers every decode lane's pending list (the
    engine enforces budget >= slots * spec segment length).
    """
    S = len(token_lists)
    take = np.zeros((S,), np.int32)
    decode_ids = [i for i in range(S)
                  if len(token_lists[i]) and decode_flags[i]]
    prefill_ids = [i for i in range(S)
                   if len(token_lists[i]) and not decode_flags[i]]
    for i in decode_ids:
        take[i] = len(token_lists[i])
    left = budget - int(take.sum())
    assert left >= 0, "token budget below live decode lanes"
    if prefill_ids:
        # rotate over SLOT indices (not list positions): the start slot
        # cycles 0..S-1, so every pending lane is first in the grant order
        # at least once every S ticks even as lanes join/leave the set
        start = rotate % S
        prefill_ids = ([i for i in prefill_ids if i >= start]
                       + [i for i in prefill_ids if i < start])
    pleft = min(left, prefill_cap) if prefill_cap else left
    for i in prefill_ids:                       # round 1: liveness
        if pleft <= 0:
            break
        take[i] = 1
        pleft -= 1
    for i in prefill_ids:                       # round 2: greedy fill
        if pleft <= 0:
            break
        extra = min(len(token_lists[i]) - int(take[i]), pleft)
        take[i] += extra
        pleft -= extra
    tokens = np.zeros((budget,), np.int32)
    tok_slot = np.zeros((budget,), np.int32)
    tok_pos = np.full((budget,), -1, np.int32)
    seg_last = np.full((S,), -1, np.int32)
    off = 0
    for i in decode_ids + sorted(prefill_ids):
        n = int(take[i])
        if n == 0:
            continue
        tokens[off:off + n] = token_lists[i][:n]
        tok_slot[off:off + n] = i
        tok_pos[off:off + n] = positions[i] + np.arange(n)
        off += n
        seg_last[i] = off - 1
    return PackedTick(tokens, tok_slot, tok_pos, seg_last, take, off)


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray                 # (P,) int token ids
    max_new: int
    sampling: SP.SamplingParams = SP.SamplingParams()
    generated: list = dataclasses.field(default_factory=list)
    pos: int = 0                       # tokens of context written to cache
    done: bool = False
    truncated: bool = False            # hit the context cap / rejected
    preemptions: int = 0
    arrival: int = -1                  # submit order (preemption priority)
    submit_tick: int = -1
    finish_tick: int = -1
    # observability (wall clocks are time.perf_counter seconds)
    submit_time: float = 0.0
    queued_tick: int = -1              # last (re-)queue tick, for queue wait
    last_token_time: float = 0.0
    decoding: bool = False             # per-residency phase (reset on preempt)
    # prefix-cache plumbing (EngineConfig.prefix_cache)
    pin_prefix: bool = False           # park this prefix pinned (no eviction)
    prefix_hit_tokens: int = 0         # cached tokens mapped at last admission
    # block 1's first-attention signal at position len(prompt)-1, captured
    # the tick the first token is sampled; cached with the prefix so a
    # full-prompt hit seeds cache["a1_sig"] instead of re-running block 0
    prefix_sig: Optional[np.ndarray] = None

    def known(self) -> list:
        """Context to teacher-force: prompt + everything sampled so far."""
        return list(self.prompt) + self.generated


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Paged-engine knobs (see ROADMAP.md 'Serving')."""
    page_size: int = 16
    num_pages: int = 64                # pool size incl. scratch page 0
    slots: int = 4                     # concurrent batch lanes
    prefill_chunk: int = 16            # max prefill tokens per lane per tick
    # flat tokens per packed dispatch; 0 = auto (slots + prefill_chunk - 1:
    # one full prefill chunk plus a decode token for every other lane).
    # Must cover at least one token per slot (liveness)
    token_budget: int = 0
    # fairness knob: cap on TOTAL prefill tokens per tick so a prefill
    # burst can never crowd decode lanes out of the budget (0 = uncapped;
    # decode lanes are packed first regardless)
    max_prefill_tokens: int = 0
    max_seq: int = 256                 # per-request context cap
    admission: str = "prompt"          # 'prompt' | 'full'
    cache_dtype: str = "float32"
    # quantized KV page storage ("" | "bf16" | "int8" | "fp8"): "" keeps
    # cache_dtype pools (the legacy bit-preserved path); int8/fp8 store
    # narrow pages plus per-page-row fp32 scale pools that the paged
    # kernels dequantize at the VMEM load — ~2x concurrent requests per
    # HBM byte at equal num_pages, with greedy token streams identical to
    # bf16 on the bench workloads (see tests/test_quantized_kv.py)
    kv_dtype: str = ""
    # MHA||MLP branch-parallel decode dispatch off the cached per-slot FAL
    # signal (plan.dual_branch; fal/parallel-family connections only —
    # ExecutionPlan.validate rejects the rest).  Logits are bit-identical
    # to sequential decode on the CPU dispatch path (the fused TPU kernel
    # is tolerance-close); the win is overlap of the paged KV gather with
    # the FFN matmuls.
    dual_branch: bool = False
    # radix prefix cache over page-aligned finished prefixes: admission
    # longest-prefix matches the prompt, shares the cached pages into the
    # block table (COW on write) and seeds the FAL a1_sig on full-prompt
    # hits.  max_cached_prefix_pages caps the tree's own page budget
    # (0 = bounded only by the pool; LRU eviction under pressure either way)
    prefix_cache: bool = False
    max_cached_prefix_pages: int = 0
    # self-speculative decoding (the FAL early-exit draft): spec_tokens is
    # the tokens each decode lane PROPOSES per tick (its packed segment
    # length; 0 = off, >= 2 on), draft_blocks how many leading blocks
    # (block 0 included) the draft path runs before its LM head.  The
    # draft, the verify and the fused sampling all live in the engine's
    # ONE jitted dispatch per tick; exact-match acceptance keeps greedy
    # and seeded token streams bit-identical to non-speculative decode
    spec_tokens: int = 0
    draft_blocks: int = 2


class PagedEngine:
    """Slot-based continuous batching over paged KV (decoder family).

    ``metrics``: a ``repro.obs.MetricsRegistry`` (one is created per engine
    when omitted — benchmarks driving several engines keep their series
    separate).  ``tracer``: a ``repro.obs.Tracer``; the default NULL tracer
    records nothing and costs one no-op context per span site."""

    def __init__(self, cfg, params, engine_cfg: EngineConfig = EngineConfig(),
                 plan=None, metrics: Optional[MetricsRegistry] = None,
                 tracer=None):
        if cfg.family not in M.PAGED_FAMILIES:
            raise NotImplementedError(cfg.family)
        if cfg.n_image_tokens:
            # model.paged_decode_step supports image_embeds, but the engine's
            # request/step plumbing is text-only — refuse rather than serve
            # image prefixes as text tokens (silently wrong logits)
            raise NotImplementedError(
                "PagedEngine serves text-only requests; vlm image prefixes "
                "need image_embeds plumbed through ServeRequest")
        assert engine_cfg.admission in ("prompt", "full"), engine_cfg.admission
        self.cfg, self.params, self.ecfg = cfg, params, engine_cfg
        self.spec = int(engine_cfg.spec_tokens)
        if self.spec:
            if self.spec < 2:
                raise ValueError(
                    f"spec_tokens={self.spec}: needs >= 2 (the lane's "
                    f"pending token + at least one proposal), or 0 = off")
            if not 1 <= engine_cfg.draft_blocks < cfg.n_layers:
                raise ValueError(
                    f"draft_blocks={engine_cfg.draft_blocks} must satisfy "
                    f"1 <= draft_blocks < n_layers={cfg.n_layers}")
        # every decode lane needs spec_tokens rows under speculation; the
        # auto budget generalises slots + chunk - 1 accordingly
        seg = max(1, self.spec)
        self.budget = engine_cfg.token_budget or (
            engine_cfg.slots * seg + engine_cfg.prefill_chunk - 1)
        if self.budget < engine_cfg.slots * seg:
            raise ValueError(
                f"token_budget={self.budget} cannot keep all "
                f"{engine_cfg.slots} slots live (need >= slots * "
                f"{seg} packed rows per decode lane)")
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # the engine stores a typed plan, not a context dict; every jitted
        # dispatch it compiles runs under this plan with phase=paged
        self.plan = ExecutionPlan.resolve(plan).with_phase(Phase.PAGED)
        if engine_cfg.dual_branch:
            self.plan = self.plan.with_dual_branch()
        self.plan.validate(cfg)
        self.max_blocks = pages_needed(engine_cfg.max_seq,
                                       engine_cfg.page_size)
        self.cache = M.init_paged_cache(
            cfg, engine_cfg.num_pages, engine_cfg.page_size,
            engine_cfg.slots, engine_cfg.cache_dtype,
            kv_dtype=engine_cfg.kv_dtype)
        # two sampler variants of the one jitted program, built lazily:
        # the fast partial-top-k sampler when every lane's params qualify
        # (SP.fast_eligible, checked host-side per tick), the full-sort
        # reference otherwise — either way ONE dispatch per tick
        self._step_fns = {}
        # HBM bytes per page across every layer's pools (scale pools
        # included, a1_sig excluded) — the allocator turns page pressure
        # into byte pressure (engine_kv_bytes_in_use / stats()["page_bytes"])
        page_bytes = sum(
            leaf.size * leaf.dtype.itemsize // engine_cfg.num_pages
            for leaf in jax.tree.leaves(
                {k: self.cache[k] for k in ("block0", "blocks")}))
        self.allocator = PageAllocator(engine_cfg.num_pages,
                                       engine_cfg.page_size,
                                       metrics=self.metrics,
                                       page_bytes=page_bytes)
        self.tables = [BlockTable(self.allocator, self.max_blocks)
                       for _ in range(engine_cfg.slots)]
        self.pcache: Optional[PrefixCache] = None
        self._cow_fn = None
        if engine_cfg.prefix_cache:
            self.pcache = PrefixCache(
                self.allocator, max_pages=engine_cfg.max_cached_prefix_pages,
                metrics=self.metrics, tracer=self.tracer)
            # per-page device memcpy across every layer's pools; the cache
            # is donated so the Pallas path rewrites the pools in place
            self._cow_fn = jax.jit(M.copy_paged_pages, donate_argnums=(0,))
        self.slots: List[Optional[ServeRequest]] = [None] * engine_cfg.slots
        self.queue: List[ServeRequest] = []
        self.finished: List[ServeRequest] = []
        self.ticks = 0
        self.packed_calls = 0
        self.dispatches = 0
        self.dispatch_ticks = 0        # ticks that issued >= 1 dispatch
        self._arrival = 0
        # registered up front so reset()/export enumerate a stable set
        self._c_ticks = self.metrics.counter(
            "engine_ticks_total", unit="ticks", site=_SITE)
        self._c_dispatches = self.metrics.counter(
            "engine_dispatches_total", unit="calls", site=_SITE)
        self._c_packed = self.metrics.counter(
            "engine_packed_calls_total", unit="calls", site=_SITE)
        self._c_prefill_toks = self.metrics.counter(
            "engine_prefill_tokens_total", unit="tokens", site=_SITE)
        self._c_decode_toks = self.metrics.counter(
            "engine_decode_tokens_total", unit="tokens", site=_SITE)
        self._c_preempt = self.metrics.counter(
            "engine_preemptions_total", unit="events", site=_SITE)
        self._c_rejected = self.metrics.counter(
            "engine_rejected_total", unit="events", site=_SITE)
        self._c_admitted = self.metrics.counter(
            "engine_admitted_total", unit="events", site=_SITE)
        self._c_finished = self.metrics.counter(
            "engine_finished_total", unit="events", site=_SITE)
        self._h_occ = self.metrics.histogram(
            "engine_occupancy", unit="ratio", site=_SITE)
        self._h_util = self.metrics.histogram(
            "engine_page_utilization", unit="ratio", site=_SITE)
        self._h_queue_wait = self.metrics.histogram(
            "engine_queue_wait_ticks", unit="ticks", site=_SITE)
        self._h_ttft_ms = self.metrics.histogram(
            "engine_ttft_ms", unit="ms", site=_SITE)
        self._h_ttft_ticks = self.metrics.histogram(
            "engine_ttft_ticks", unit="ticks", site=_SITE)
        self._h_itl_ms = self.metrics.histogram(
            "engine_inter_token_ms", unit="ms", site=_SITE)
        self._h_req_ticks = self.metrics.histogram(
            "engine_request_latency_ticks", unit="ticks", site=_SITE)
        self._h_dispatch_ms = self.metrics.histogram(
            "engine_dispatch_ms", unit="ms", site=_SITE)
        self._h_tok_disp = self.metrics.histogram(
            "engine_tokens_per_dispatch", unit="tokens", site=_SITE)
        self._h_pad_frac = self.metrics.histogram(
            "engine_padding_fraction", unit="ratio", site=_SITE)
        self._c_cow = self.metrics.counter(
            "engine_cow_copies_total", unit="pages", site=_SITE)
        self._c_sig_seeded = self.metrics.counter(
            "engine_a1_sig_seeded_total", unit="events", site=_SITE)
        self._h_ttft_hit_ms = self.metrics.histogram(
            "engine_ttft_hit_ms", unit="ms", site=_SITE)
        self._h_ttft_cold_ms = self.metrics.histogram(
            "engine_ttft_cold_ms", unit="ms", site=_SITE)
        self._h_ttft_hit_ticks = self.metrics.histogram(
            "engine_ttft_hit_ticks", unit="ticks", site=_SITE)
        self._h_ttft_cold_ticks = self.metrics.histogram(
            "engine_ttft_cold_ticks", unit="ticks", site=_SITE)
        self._c_spec_acc = self.metrics.counter(
            "engine_spec_accepted_total", unit="tokens", site=_SITE)
        self._c_spec_rej = self.metrics.counter(
            "engine_spec_rejected_total", unit="tokens", site=_SITE)
        self._h_spec_len = self.metrics.histogram(
            "engine_spec_accepted_len", unit="tokens", site=_SITE)

    # ------------------------------------------------------------------ #
    def submit(self, req: ServeRequest):
        req.arrival = self._arrival
        self._arrival += 1
        req.submit_tick = self.ticks
        req.queued_tick = self.ticks
        req.submit_time = time.perf_counter()
        self.queue.append(req)
        self.tracer.begin_async("req", req.rid, prompt_len=len(req.prompt),
                                max_new=req.max_new)
        self.tracer.instant("QUEUED", rid=req.rid)

    def _admission_pages(self, r: ServeRequest) -> int:
        ctx = len(r.known())
        ahead = ctx + (r.max_new - len(r.generated)) \
            if self.ecfg.admission == "full" else ctx + 1
        return pages_needed(min(ahead, self.ecfg.max_seq),
                            self.ecfg.page_size)

    def _reject(self, r: ServeRequest):
        r.done = r.truncated = True
        r.finish_tick = self.ticks
        self._c_rejected.inc()
        self.finished.append(r)
        self.tracer.instant("REJECTED", rid=r.rid)
        self.tracer.end_async("req", r.rid, outcome="rejected")

    def _admit(self):
        while self.queue:
            try:
                free = self.slots.index(None)
            except ValueError:
                return
            r = self.queue[0]
            ctx = len(r.known())
            need = self._admission_pages(r)
            # requests that can never complete are rejected instead of
            # deadlocking the queue (or livelocking the pool): the context
            # must fit max_seq with room to sample at least one token, and
            # its pages must fit the pool
            if (ctx + 1 > self.ecfg.max_seq
                    or need > min(self.max_blocks, self.allocator.capacity)):
                self.queue.pop(0)
                self._reject(r)
                continue
            # longest-prefix match; the provisional ``share`` keeps matched
            # pages at refcount > 1 through any eviction below, so a
            # just-matched node can never be freed out from under us
            n_hit, hit_pages, hit_a1 = 0, [], {}
            if self.pcache is not None and ctx > 1:
                n_hit, hit_pages, hit_a1 = self.pcache.match(
                    np.asarray(r.known(), np.int64))
                if hit_pages:
                    self.allocator.share(hit_pages)
            need_new = need - len(hit_pages)
            if not self.allocator.can_alloc(need_new):
                if self.pcache is not None:
                    self.pcache.evict(need_new - self.allocator.free_pages)
                if not self.allocator.can_alloc(need_new):
                    if hit_pages:           # drop the provisional hold
                        self.allocator.free(hit_pages)
                    return                   # FCFS: no head-of-line skipping
            self.queue.pop(0)
            # (re-)prefill from the divergence point; a full-prompt hit
            # (n_hit == ctx) enters decode on its first tick — the last
            # prompt token runs as a one-token decode segment (its page is
            # COW'd out of the shared span before the write)
            r.pos = min(n_hit, ctx - 1)
            r.decoding = False
            r.prefix_hit_tokens = n_hit
            self.tables[free].adopt(hit_pages)
            self.slots[free] = r
            self._c_admitted.inc()
            self._h_queue_wait.record(self.ticks - r.queued_tick)
            self.tracer.instant("ADMITTED", rid=r.rid, slot=free,
                                wait_ticks=self.ticks - r.queued_tick)
            if self.pcache is not None:
                self.pcache.note_admission(n_hit)
            if n_hit:
                self.tracer.instant("PREFIX_HIT", rid=r.rid, slot=free,
                                    hit_tokens=n_hit,
                                    shared_pages=len(hit_pages))
                # seed the FAL signal from the cached entry on decode
                # entry: the paper's redirected first-attention output at
                # position pos is a pure function of tokens [0, pos], so
                # the stored artifact replaces block 0's assemble
                if r.pos == ctx - 1 and r.pos in hit_a1:
                    sig = jnp.asarray(hit_a1[r.pos],
                                      self.cache["a1_sig"].dtype)
                    self.cache["a1_sig"] = \
                        self.cache["a1_sig"].at[free].set(sig)
                    self._c_sig_seeded.inc()
            self.tracer.instant("PREFILL", rid=r.rid, slot=free,
                                context=ctx, from_pos=r.pos)
            if self.ecfg.admission == "full":
                # reservation policy: actually hold the worst-case pages now
                # so this request can never be preempted for page pressure
                ok = self.tables[free].ensure(
                    min(ctx + r.max_new - len(r.generated),
                        self.ecfg.max_seq))
                assert ok                    # can_alloc(need) just passed

    # ------------------------------------------------------------------ #
    def _preempt(self, i: int):
        r = self.slots[i]
        # release() drops this request's REFERENCES only: pages shared with
        # the prefix cache stay allocated (the tree's refcount holds them),
        # so re-admission longest-prefix matches the still-cached prefix
        # and re-prefills from the divergence point, not token 0
        self.tables[i].release()
        r.pos = 0
        r.decoding = False
        r.preemptions += 1
        r.queued_tick = self.ticks
        self._c_preempt.inc()
        self.slots[i] = None
        self.queue.insert(0, r)              # front: resumes before new work
        self.tracer.instant("PREEMPTED", rid=r.rid, slot=i,
                            generated=len(r.generated))

    def _pick_victim(self, exclude: int) -> Optional[int]:
        cands = [i for i, r in enumerate(self.slots)
                 if r is not None and i != exclude]
        if not cands:
            return None
        return max(cands, key=lambda i: self.slots[i].arrival)  # youngest

    def _relieve_pressure(self, exclude: int) -> bool:
        """Free page capacity under pressure, cheapest first: evict
        refcount-free prefix-cache entries (no recompute lost — only idle
        cached prefixes), then preempt the youngest other active request.
        False => nothing left to take (caller must preempt itself)."""
        if self.pcache is not None and self.pcache.evict(1):
            return True
        victim = self._pick_victim(exclude=exclude)
        if victim is None:
            return False
        self._preempt(victim)
        return True

    def _ensure(self, i: int, new_len: int) -> bool:
        """Grow slot i's block table to cover new_len tokens AND privatise
        (copy-on-write) any prefix-shared page in this tick's write range
        [pos, new_len), relieving page pressure as needed.  False => slot i
        was itself preempted (or finished truncated) and is gone."""
        if pages_needed(new_len, self.ecfg.page_size) \
                > min(self.max_blocks, self.allocator.capacity):
            # infeasible no matter how many victims are evicted (would
            # livelock the while-loop below): finish truncated instead
            self._finish(i, truncated=True)
            return False
        while not self.tables[i].ensure(new_len):
            if not self._relieve_pressure(exclude=i):
                self._preempt(i)
                return False
        if self.pcache is None:
            return True
        # COW: the packed tick will scatter K/V for positions [pos,
        # new_len); any page there still shared with the tree (or another
        # sharer) gets a private device copy first, so the write can never
        # leak into another request's history.  Only the divergence
        # boundary page is ever shared, so this runs at most once per
        # admission in steady state.
        r = self.slots[i]
        while True:
            blk = self.tables[i].first_shared_block(r.pos, new_len)
            if blk is None:
                return True
            got = self.allocator.alloc(1)
            if got is None:
                if not self._relieve_pressure(exclude=i):
                    self._preempt(i)
                    return False
                continue
            old = self.tables[i].pages[blk]
            with self.tracer.span("engine.cow", annotate=True,
                                  page_from=old, page_to=got[0]):
                self.cache = self._cow_fn(
                    self.cache, jnp.asarray([old], jnp.int32),
                    jnp.asarray([got[0]], jnp.int32))
            self.tables[i].replace(blk, got[0])
            self._c_cow.inc()
            self.tracer.instant("COW", rid=r.rid, slot=i, block=blk,
                                page_from=old, page_to=got[0])

    def _park_prefix(self, i: int, r: ServeRequest):
        """Insert the finished request's page-aligned written prefix (and
        its captured a1_sig at the prompt's last position) into the radix
        tree.  Runs BEFORE ``release()``: ``insert`` takes the tree's own
        refcount on newly-cached pages, release then drops the table's."""
        ps = self.ecfg.page_size
        n_ins = (r.pos // ps) * ps       # only fully-written pages
        if n_ins <= 0:
            return
        a1 = {}
        q = len(r.prompt) - 1
        if r.prefix_sig is not None and q < n_ins:
            a1[q] = r.prefix_sig
        adopted = self.pcache.insert(
            np.asarray(r.known()[:n_ins], np.int64),
            self.tables[i].pages[:n_ins // ps], a1=a1,
            pinned=r.pin_prefix)
        if adopted:
            self.tracer.instant("PREFIX_PARKED", rid=r.rid,
                                pages=adopted, tokens=n_ins)

    def _finish(self, i: int, truncated: bool = False):
        r = self.slots[i]
        if self.pcache is not None:
            self._park_prefix(i, r)
        r.done = True
        r.truncated = truncated
        r.finish_tick = self.ticks
        self.tables[i].release()
        self.slots[i] = None
        self.finished.append(r)
        self._c_finished.inc()
        self._h_req_ticks.record(r.finish_tick - r.submit_tick)
        self.tracer.instant("FINISHED", rid=r.rid, truncated=truncated,
                            generated=len(r.generated))
        self.tracer.end_async(
            "req", r.rid, outcome="truncated" if truncated else "finished")

    # ------------------------------------------------------------------ #
    def _spec_eligible(self, r: ServeRequest) -> bool:
        """A decode lane speculates when (a) speculation is on, (b) it has
        already sampled its first token — the first-token tick runs
        non-speculatively so the prefix-cache ``a1_sig`` artifact is
        captured at the prompt's true last position — and (c) a full
        n-token proposal fits under ``max_seq`` (no variable-length spec
        segments: near the cap the lane falls back to plain decode)."""
        return (self.spec > 0 and len(r.generated) > 0
                and r.pos + self.spec <= self.ecfg.max_seq)

    def _plan_pack(self) -> PackedTick:
        """Pack this tick's pending context into one flat token buffer:
        each active lane offers up to ``prefill_chunk`` tokens when
        prefilling — granted in rotating round-robin order (the tick
        counter advances the start index, so no pending lane starves
        under budget pressure) — or, when decoding, its pending token
        plus ``spec_tokens - 1`` placeholder rows the device's draft loop
        fills in; ``pack_tokens`` fits them into the engine's token
        budget, decode lanes first."""
        lists, poss, dec = [], [], []
        for r in self.slots:
            if r is None:
                lists.append([])
                poss.append(0)
                dec.append(False)
                continue
            decoding = len(r.known()) - r.pos == 1
            if decoding and self._spec_eligible(r):
                # the lane's one pending token + n-1 placeholders: rows
                # pos+1..pos+n-1 are proposed ON DEVICE by the draft loop
                lists.append(r.known()[r.pos:] + [0] * (self.spec - 1))
            else:
                lists.append(r.known()[r.pos:r.pos + self.ecfg.prefill_chunk])
            poss.append(r.pos)
            dec.append(decoding)
        return pack_tokens(lists, poss, dec, self.budget,
                           self.ecfg.max_prefill_tokens, rotate=self.ticks)

    def _consume_one(self, i: int, tok: int, now: float):
        """Append one sampled token to lane i (the plain packed-tick emit
        path: first-token artifacts, TTFT/ITL series, finish checks)."""
        r = self.slots[i]
        r.generated.append(tok)
        if len(r.generated) == 1:
            if self.pcache is not None and r.prefix_sig is None:
                # block 1's first-attention signal at position
                # len(prompt)-1 (this tick's seg_last row), the
                # prefix artifact _park_prefix caches at finish
                r.prefix_sig = np.asarray(self.cache["a1_sig"][i])
            ttft_ms = (now - r.submit_time) * 1e3
            ttft_ticks = self.ticks - r.submit_tick
            self._h_ttft_ms.record(ttft_ms)
            self._h_ttft_ticks.record(ttft_ticks)
            if self.pcache is not None:
                hot = r.prefix_hit_tokens > 0
                (self._h_ttft_hit_ms if hot
                 else self._h_ttft_cold_ms).record(ttft_ms)
                (self._h_ttft_hit_ticks if hot
                 else self._h_ttft_cold_ticks).record(ttft_ticks)
        elif r.last_token_time:
            self._h_itl_ms.record((now - r.last_token_time) * 1e3)
        r.last_token_time = now
        if not r.decoding:
            r.decoding = True
            self.tracer.instant("DECODE", rid=r.rid, slot=i,
                                generated=len(r.generated))
        if len(r.generated) >= r.max_new:
            self._finish(i)
        elif len(r.known()) >= self.ecfg.max_seq:
            self._finish(i, truncated=True)

    def _consume_spec_lane(self, i: int, tgt_row: np.ndarray,
                           fed_row: np.ndarray, now: float):
        """Accept the longest prefix of lane i's n-1 proposals that match
        their verify targets, plus the bonus target after it; rewind the
        rejected growth.  ``tgt_row[j]`` is the model's true token at
        position pos+j+1, ``fed_row[j]`` what was packed at position
        pos+j (row 0 the real pending token, rows 1.. the proposals)."""
        n = self.spec
        r = self.slots[i]
        a = 0
        while a < n - 1 and int(fed_row[a + 1]) == int(tgt_row[a]):
            a += 1
        # the emitted stream must be exactly what sequential decode would
        # produce, truncated at the same finish boundaries
        room = min(r.max_new - len(r.generated),
                   self.ecfg.max_seq - len(r.known()))
        emit = [int(t) for t in tgt_row[:a + 1][:room]]
        r.generated.extend(emit)
        # positions pos..pos+len(emit)-1 now hold verified context; the
        # trailing rejected rows' pages are rewound (shrink drops only
        # THIS table's references — shared prefix pages survive).  Their
        # K/V stays causally invisible until the positions are re-fed.
        r.pos += len(emit)
        dropped = self.tables[i].shrink(r.pos)
        self._c_decode_toks.inc(len(emit))
        self._c_spec_acc.inc(a)
        self._c_spec_rej.inc(n - 1 - a)
        self._h_spec_len.record(len(emit))
        if dropped:
            self.tracer.instant("SPEC_ROLLBACK", rid=r.rid, slot=i,
                                pages=dropped, accepted=a)
        if r.last_token_time:
            self._h_itl_ms.record((now - r.last_token_time) * 1e3)
        r.last_token_time = now
        if not r.decoding:
            r.decoding = True
            self.tracer.instant("DECODE", rid=r.rid, slot=i,
                                generated=len(r.generated))
        if len(r.generated) >= r.max_new:
            self._finish(i)
        elif len(r.known()) >= self.ecfg.max_seq:
            self._finish(i, truncated=True)

    def _step_for(self, fast):
        """The tick's jitted program with the fast or reference sampler
        fused in (at most two compiled variants per engine)."""
        if fast not in self._step_fns:
            samp = SP.fast_sampler(self.cfg.vocab) if fast else None
            if self.spec:
                self._step_fns[fast] = make_spec_step(
                    self.cfg, self.plan, spec_tokens=self.spec,
                    draft_blocks=self.ecfg.draft_blocks, sampler=samp)
            else:
                self._step_fns[fast] = make_packed_step(
                    self.cfg, self.plan, sampler=samp)
        return self._step_fns[fast]

    def _run_packed(self, pt: PackedTick):
        """One jitted engine call (forward + fused sampling) over a packed
        token buffer; consume samples for every request whose context
        completed this call.  Lanes may be in DIFFERENT phases: lane i
        advances its ``pt.n_taken[i]`` packed tokens (under speculation a
        decode lane's segment spans its whole n-token proposal and may
        emit up to n tokens)."""
        S = self.ecfg.slots
        ids = [i for i in range(S) if pt.n_taken[i] > 0]
        self.dispatches += 1
        self._c_dispatches.inc()
        self._h_occ.record(len(ids) / S)
        T = pt.tokens.shape[0]
        self._h_tok_disp.record(pt.n_live)
        self._h_pad_frac.record(1.0 - pt.n_live / T)
        bt = np.stack([t.as_row() for t in self.tables])
        temps = np.zeros((S,), np.float32)
        ks = np.zeros((S,), np.int32)
        ps = np.ones((S,), np.float32)
        seeds = np.zeros((S,), np.int32)
        poss = np.zeros((S,), np.int32)
        spec_mask = np.zeros((S,), bool)
        for i in ids:
            r = self.slots[i]
            sp = r.sampling
            temps[i], ks[i], ps[i] = sp.temperature, sp.top_k, sp.top_p
            seeds[i] = sp.seed
            # position of the would-be new token (== len(known()) exactly
            # when this call completes the request's context)
            poss[i] = r.pos + int(pt.n_taken[i])
            # a decode lane whose segment spans > 1 row is speculating
            # (only _plan_pack's spec-eligible lanes pack that way)
            spec_mask[i] = (len(r.known()) - r.pos == 1
                            and int(pt.n_taken[i]) > 1)
        step_fn = self._step_for(all(
            SP.fast_eligible(self.slots[i].sampling, self.cfg.vocab)
            for i in ids))
        t0 = time.perf_counter()
        if self.spec:
            with self.tracer.span("engine.dispatch", annotate=True,
                                  lanes=len(ids), live_tokens=pt.n_live,
                                  budget=T, spec_lanes=int(spec_mask.sum())):
                tgt, fed, self.cache = step_fn(
                    self.params, self.cache, jnp.asarray(pt.tokens),
                    jnp.asarray(pt.tok_slot), jnp.asarray(pt.tok_pos),
                    jnp.asarray(bt), jnp.asarray(pt.seg_last),
                    jnp.asarray(spec_mask), jnp.asarray(temps),
                    jnp.asarray(ks), jnp.asarray(ps), jnp.asarray(seeds))
            self._h_dispatch_ms.record((time.perf_counter() - t0) * 1e3)
            tgt_np, fed_np = np.asarray(tgt), np.asarray(fed)
            now = time.perf_counter()
            for i in ids:
                r = self.slots[i]
                adv = int(pt.n_taken[i])
                if spec_mask[i]:
                    # pos/decode-token accounting live inside the helper:
                    # only the ACCEPTED prefix advances the lane
                    self._consume_spec_lane(i, tgt_np[i], fed_np[i], now)
                    continue
                if len(r.known()) - r.pos == 1:
                    self._c_decode_toks.inc(adv)
                else:
                    self._c_prefill_toks.inc(adv)
                r.pos += adv
                if r.pos == len(r.known()):
                    # non-spec lane: the verify pass's last column is the
                    # plain packed-tick sample at position pos
                    self._consume_one(i, int(tgt_np[i][self.spec - 1]), now)
            return
        with self.tracer.span("engine.dispatch", annotate=True,
                              lanes=len(ids), live_tokens=pt.n_live,
                              budget=T):
            _, nxt, self.cache = step_fn(
                self.params, self.cache, jnp.asarray(pt.tokens),
                jnp.asarray(pt.tok_slot), jnp.asarray(pt.tok_pos),
                jnp.asarray(bt), jnp.asarray(pt.seg_last),
                jnp.asarray(temps), jnp.asarray(ks), jnp.asarray(ps),
                jnp.asarray(seeds), jnp.asarray(poss))
        self._h_dispatch_ms.record((time.perf_counter() - t0) * 1e3)
        for i in ids:
            r = self.slots[i]
            adv = int(pt.n_taken[i])
            if len(r.known()) - r.pos == 1:
                self._c_decode_toks.inc(adv)
            else:
                self._c_prefill_toks.inc(adv)
            r.pos += adv
        need = [i for i in ids
                if self.slots[i].pos == len(self.slots[i].known())]
        if need:
            nxt_np = np.asarray(nxt)
            now = time.perf_counter()
            for i in need:
                self._consume_one(i, int(nxt_np[i]), now)

    # ------------------------------------------------------------------ #
    def step(self):
        """One engine tick: admit, then ONE packed dispatch serving every
        active lane at its own phase."""
        self.ticks += 1
        self._c_ticks.inc()
        with self.tracer.span("engine.tick", tick=self.ticks):
            self._admit()
            d0 = self.dispatches
            self._step_packed()
            if self.dispatches > d0:
                self.dispatch_ticks += 1
            self._h_util.record(self.allocator.stats()["utilization"])

    def _step_packed(self):
        """ONE flat (token_budget,) dispatch: prefilling lanes advance up
        to ``prefill_chunk`` packed tokens, decoding lanes 1 (or pack
        their whole n-token speculative proposal), in the same jitted
        call.  Page growth (``_ensure``) can preempt or
        truncate lanes mid-plan; every eviction frees budget, so the pack
        is re-planned until the surviving lanes' plan sticks (each
        non-final iteration empties at least one slot, bounding the loop
        at slots + 1)."""
        for _ in range(self.ecfg.slots + 1):
            pt = self._plan_pack()
            if pt.n_live == 0:
                return
            replan = False
            for i in range(self.ecfg.slots):
                if pt.n_taken[i] == 0 or self.slots[i] is None:
                    continue
                if not self._ensure(i, self.slots[i].pos
                                    + int(pt.n_taken[i])):
                    replan = True             # slot i preempted/truncated
                    break
            # _ensure can also evict OTHER packed lanes as victims
            if not replan and all(
                    self.slots[i] is not None
                    for i in range(self.ecfg.slots) if pt.n_taken[i] > 0):
                self.packed_calls += 1
                self._c_packed.inc()
                self._run_packed(pt)
                return

    def run(self, max_ticks: Optional[int] = None) -> List[ServeRequest]:
        while any(s is not None for s in self.slots) or self.queue:
            if max_ticks is not None and self.ticks >= max_ticks:
                break
            self.step()
        return self.finished

    # ------------------------------------------------------------------ #
    def reset_stats(self):
        """Zero every counter/series (and drop buffered trace events) while
        keeping compiled programs, live requests and page state (benchmarks
        call this after warmup)."""
        self.ticks = 0
        self.packed_calls = 0
        self.dispatches = self.dispatch_ticks = 0
        self.metrics.reset()
        self.tracer.clear()
        self.allocator.peak_in_use = self.allocator.in_use

    def stats(self) -> dict:
        frag = sum(self.tables[i].internal_fragmentation(self.slots[i].pos)
                   for i in range(self.ecfg.slots)
                   if self.slots[i] is not None)

        def pcts(h):
            return {"p50": h.percentile(50), "p99": h.percentile(99),
                    "mean": h.mean, "count": h.count}

        return {
            "ticks": self.ticks,
            "packed_calls": self.packed_calls,
            "dispatches": self.dispatches,
            "dispatch_ticks": self.dispatch_ticks,
            # over ticks that issued any dispatch (a tick whose only lane
            # was truncated/preempted mid-growth legitimately issues
            # none): EXACTLY 1.0 under packed ticks
            "dispatches_per_tick":
                self.dispatches / max(self.dispatch_ticks, 1),
            # active lanes per dispatch / slots: packed ticks keep every
            # occupied lane advancing in every dispatch (modulo the
            # prefill-token fairness cap)
            "mean_occupancy": self._h_occ.mean,
            # the tentpole metrics: live tokens per flat dispatch and the
            # fraction of the buffer burned as padding (the padded layout
            # pays ~ 1 - (slots + chunk - 1)/(slots * chunk) here)
            "token_budget": self.budget,
            "tokens_per_dispatch": pcts(self._h_tok_disp),
            "padding_fraction": pcts(self._h_pad_frac),
            "prefill_tokens": self._c_prefill_toks.value,
            "decode_tokens": self._c_decode_toks.value,
            "preemptions": self._c_preempt.value,
            "rejected": self._c_rejected.value,
            "mean_page_utilization": self._h_util.mean,
            "internal_fragmentation": frag,
            "pages": self.allocator.stats(),
            # request-lifecycle latency summaries (the registry is the
            # source of truth; these are the headline cuts)
            "ttft_ms": pcts(self._h_ttft_ms),
            "ttft_ticks": pcts(self._h_ttft_ticks),
            "inter_token_ms": pcts(self._h_itl_ms),
            "queue_wait_ticks": pcts(self._h_queue_wait),
            "request_latency_ticks": pcts(self._h_req_ticks),
            "dispatch_ms": pcts(self._h_dispatch_ms),
            # prefix-sharing cut (None when EngineConfig.prefix_cache off):
            # radix-tree contents + hit rates, allocator sharing, COW and
            # a1_sig seeding counts, and TTFT split hot (prefix hit at
            # admission) vs cold
            # self-speculative decoding cut (None when spec_tokens == 0):
            # proposal acceptance counts/rate and the per-tick emitted
            # (accepted + bonus) length distribution — mean accepted_len
            # is the tokens-per-tick multiplier over plain decode
            "spec": None if not self.spec else {
                "spec_tokens": self.spec,
                "draft_blocks": self.ecfg.draft_blocks,
                "proposals_accepted": self._c_spec_acc.value,
                "proposals_rejected": self._c_spec_rej.value,
                "acceptance_rate": self._c_spec_acc.value / max(
                    self._c_spec_acc.value + self._c_spec_rej.value, 1),
                "accepted_len": pcts(self._h_spec_len),
            },
            "prefix": None if self.pcache is None else {
                **self.pcache.stats(),
                "shared_pages": self.allocator.shared_pages,
                "cow_copies": self._c_cow.value,
                "a1_sig_seeded": self._c_sig_seeded.value,
                "ttft_hit_ms": pcts(self._h_ttft_hit_ms),
                "ttft_cold_ms": pcts(self._h_ttft_cold_ms),
                "ttft_hit_ticks": pcts(self._h_ttft_hit_ticks),
                "ttft_cold_ticks": pcts(self._h_ttft_cold_ticks),
            },
            "metrics": self.metrics.to_dict(),
        }

    @property
    def preemptions(self) -> int:
        return self._c_preempt.value

    @property
    def rejected(self) -> int:
        return self._c_rejected.value
