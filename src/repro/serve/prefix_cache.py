"""Token-level radix tree over page-aligned cached KV prefixes — plus the
FAL first-attention signal as a cached prefix artifact.

At scale most traffic shares system prompts and few-shot preambles; without
sharing, every admission re-prefills the shared prefix from token 0 AND
re-pays block 0's assemble to rebuild ``cache["a1_sig"]``.  This module
keeps finished requests' page-aligned prefixes in a radix tree so a new
request's admission can:

* **match** the longest cached prefix of its prompt (page granularity —
  a page is reusable only if all ``page_size`` tokens agree),
* map the matched PHYSICAL pages straight into its block table (the
  allocator refcounts them; no KV bytes move), and
* **seed** ``cache["a1_sig"]`` from the entry's stored signal, because the
  FAL signal at position p is a pure function of tokens [0, p] — so a
  full-prompt hit enters decode on its first tick with no block-0 assemble
  at admission.  This is the FAL-specific win: the paper's redirected
  first-attention output is a per-request scalar artifact of the prefix,
  so it caches exactly like a KV page does.

Tree shape: children are keyed by their edge's FIRST PAGE of tokens
(``page_size`` tokens, byte-packed), every node's edge holds a whole
number of pages, and edges split only at page boundaries — two prompts
diverging mid-page simply become sibling nodes sharing no page, which is
the page-granularity sharing contract.  Each node carries its edge tokens,
the physical pages of that span (one tree-owned refcount each, taken via
``allocator.share`` at insert), an LRU stamp, and the a1_sig entries whose
positions fall inside its span.

Eviction is LRU over refcount-FREE leaves only: a leaf all of whose pages
have refcount 1 (the tree's own reference) can be dropped; a node still
shared with any live block table is never touched.  Eviction cascades —
dropping a leaf may expose its parent as the next candidate — and runs
both under allocator pressure (the engine calls ``evict`` before
preempting anyone) and against the ``max_pages`` budget
(``EngineConfig.max_cached_prefix_pages``).  ``pinned`` nodes (explicit
pinning via ``ServeRequest.pin_prefix``) are exempt.

Metrics (``prefix_*``, site serve/prefix_cache.py): ``prefix_hits_total``
/ ``prefix_misses_total`` / ``prefix_inserted_pages_total`` /
``prefix_evicted_pages_total`` counters, a ``prefix_hit_tokens``
histogram, and a ``prefix_cached_pages`` gauge; the allocator's
``pages_shared`` gauge counts pages with >1 owner.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.paged_cache import PageAllocator


class _Node:
    """One radix edge: ``tokens`` (a whole number of pages) labels the path
    from ``parent``; ``pages`` are the physical pages of that span (one
    tree refcount each); ``a1`` maps ABSOLUTE prefix positions inside this
    span to stored first-attention signals."""

    __slots__ = ("tokens", "pages", "children", "parent", "last_used",
                 "a1", "pinned")

    def __init__(self, tokens: np.ndarray, pages: List[int],
                 parent: Optional["_Node"]):
        self.tokens = tokens
        self.pages = pages
        self.children: Dict[bytes, "_Node"] = {}
        self.parent = parent
        self.last_used = 0
        self.a1: Dict[int, np.ndarray] = {}
        self.pinned = False


class PrefixCache:
    """Radix tree of page-aligned cached prefixes over a ``PageAllocator``.

    The tree holds one refcount per cached page; requests that hit gain
    their own refcount via ``allocator.share`` (done by the engine before
    adopting, so a concurrent eviction can never free a just-matched
    page).  ``max_pages`` = 0 means no budget beyond the pool itself."""

    def __init__(self, allocator: PageAllocator, max_pages: int = 0,
                 metrics=None, tracer=None):
        self.alloc = allocator
        self.page = allocator.page_size
        self.max_pages = max_pages
        self.root = _Node(np.zeros((0,), np.int64), [], None)
        self.n_pages = 0
        self._clock = 0
        self.metrics = metrics
        self.tracer = tracer
        if metrics is not None:
            site = "serve/prefix_cache.py"
            self._c_hit = metrics.counter("prefix_hits_total",
                                          unit="admissions", site=site)
            self._c_miss = metrics.counter("prefix_misses_total",
                                           unit="admissions", site=site)
            self._c_ins = metrics.counter("prefix_inserted_pages_total",
                                          unit="pages", site=site)
            self._c_evict = metrics.counter("prefix_evicted_pages_total",
                                            unit="pages", site=site)
            self._h_hit_tokens = metrics.histogram("prefix_hit_tokens",
                                                   unit="tokens", site=site)
            self._g_pages = metrics.gauge("prefix_cached_pages",
                                          unit="pages", site=site)

    # -- helpers ----------------------------------------------------------

    def _key(self, tokens: np.ndarray) -> bytes:
        return np.ascontiguousarray(tokens[:self.page]).tobytes()

    @staticmethod
    def _canon(tokens) -> np.ndarray:
        return np.asarray(tokens, dtype=np.int64).reshape(-1)

    def _match_pages(self, edge: np.ndarray, query: np.ndarray) -> int:
        """Number of leading whole pages on which ``edge`` and ``query``
        agree."""
        ps = self.page
        lim = min(len(edge), len(query)) // ps
        m = 0
        while m < lim and np.array_equal(edge[m * ps:(m + 1) * ps],
                                         query[m * ps:(m + 1) * ps]):
            m += 1
        return m

    def _observe(self):
        if self.metrics is not None:
            self._g_pages.set(self.n_pages)

    # -- queries ----------------------------------------------------------

    def match(self, tokens) -> Tuple[int, List[int], Dict[int, np.ndarray]]:
        """Longest page-aligned cached prefix of ``tokens``.

        Returns ``(n_hit, pages, a1)``: ``n_hit`` matched tokens (a
        multiple of page_size), the physical pages covering them in order,
        and the stored a1_sig entries at absolute positions < n_hit.  The
        caller must ``allocator.share(pages)`` before anything that could
        evict (the match itself holds no reference).  Touches LRU stamps on
        the walked path."""
        tokens = self._canon(tokens)
        self._clock += 1
        node, n = self.root, 0
        pages: List[int] = []
        a1: Dict[int, np.ndarray] = {}
        node.last_used = self._clock
        while len(tokens) - n >= self.page:
            child = node.children.get(self._key(tokens[n:]))
            if child is None:
                break
            m = self._match_pages(child.tokens, tokens[n:])
            if m == 0:        # hash collision across dtypes can't happen;
                break         # defensive: first page must match by key
            child.last_used = self._clock
            pages.extend(child.pages[:m])
            end = n + m * self.page
            for q, sig in child.a1.items():
                if q < end:
                    a1[q] = sig
            n = end
            if m < len(child.pages):
                break
            node = child
        return n, pages, a1

    def note_admission(self, hit_tokens: int) -> None:
        """Engine callback on a SUCCESSFUL admission: records hit/miss
        counters and the hit-length histogram (kept out of ``match`` so
        FCFS retries of a blocked head-of-queue don't inflate the rate)."""
        if self.metrics is None:
            return
        if hit_tokens > 0:
            self._c_hit.inc()
            self._h_hit_tokens.record(hit_tokens)
        else:
            self._c_miss.inc()

    # -- mutation ---------------------------------------------------------

    def _split(self, node: "_Node", parent: "_Node", keep_pages: int,
               abs_start: int) -> "_Node":
        """Split ``node``'s edge after ``keep_pages`` pages; returns the new
        upper node.  Pages keep their single tree refcount (they just move
        between nodes); a1 entries are distributed by absolute position."""
        ps = self.page
        cut = keep_pages * ps
        upper = _Node(node.tokens[:cut], node.pages[:keep_pages], parent)
        upper.last_used = node.last_used
        upper.pinned = node.pinned
        parent.children[self._key(upper.tokens)] = upper
        node.tokens = node.tokens[cut:]
        node.pages = node.pages[keep_pages:]
        node.parent = upper
        upper.children[self._key(node.tokens)] = node
        split_abs = abs_start + cut
        for q in [q for q in node.a1 if q < split_abs]:
            upper.a1[q] = node.a1.pop(q)
        return upper

    def insert(self, tokens, pages: List[int],
               a1: Optional[Dict[int, np.ndarray]] = None,
               pinned: bool = False) -> int:
        """Cache the page-aligned prefix ``tokens`` whose KV lives in
        ``pages`` (still owned by the inserting request's block table — the
        tree takes its OWN refcount on every newly-cached page via
        ``allocator.share``).  ``a1`` maps absolute positions to
        first-attention signals valid for this prefix.  Returns the number
        of pages newly adopted; enforces ``max_pages`` afterwards by LRU
        eviction (never evicting pinned nodes)."""
        tokens = self._canon(tokens)
        ps = self.page
        assert len(tokens) % ps == 0 and len(pages) == len(tokens) // ps
        a1 = dict(a1 or {})
        self._clock += 1
        node, n, adopted = self.root, 0, 0
        path: List[Tuple[int, "_Node"]] = []      # (abs_start, node)
        node.last_used = self._clock
        while n < len(tokens):
            child = node.children.get(self._key(tokens[n:]))
            if child is None:
                fresh = tokens[n:]
                fresh_pages = list(pages[n // ps:])
                self.alloc.share(fresh_pages)
                new = _Node(fresh, fresh_pages, node)
                new.last_used = self._clock
                node.children[self._key(fresh)] = new
                path.append((n, new))
                adopted += len(fresh_pages)
                self.n_pages += len(fresh_pages)
                n = len(tokens)
                break
            m = self._match_pages(child.tokens, tokens[n:])
            if m == 0:
                # same first-page key but different tokens is impossible
                # (the key IS the first page); defensive stop.
                break
            if m < len(child.pages):
                child = self._split(child, node, m, abs_start=n)
            child.last_used = self._clock
            path.append((n, child))
            n += len(child.tokens)
            node = child
        # pin + a1 attach along the covered path
        for abs_start, nd in path:
            span = len(nd.tokens)
            if pinned:
                nd.pinned = True
            for q in [q for q in a1 if abs_start <= q < abs_start + span]:
                nd.a1[q] = a1.pop(q)
        if self.metrics is not None and adopted:
            self._c_ins.inc(adopted)
        self._observe()
        if self.max_pages and self.n_pages > self.max_pages:
            self.evict(self.n_pages - self.max_pages)
        return adopted

    def _evictable_leaves(self) -> List["_Node"]:
        out, stack = [], [self.root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if (nd is not self.root and not nd.children and not nd.pinned
                    and all(self.alloc.refcount(pg) == 1
                            for pg in nd.pages)):
                out.append(nd)
        return out

    def evict(self, n_pages: int) -> int:
        """Free at least ``n_pages`` cached pages by dropping LRU leaves
        whose pages carry no reference beyond the tree's own (a node shared
        with any live block table is never evicted).  Cascades: removing a
        leaf may expose its parent.  Returns pages actually freed (may be
        less if everything left is referenced or pinned)."""
        freed = 0
        while freed < n_pages:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_used)
            self.alloc.free(victim.pages)
            freed += len(victim.pages)
            self.n_pages -= len(victim.pages)
            victim.parent.children.pop(self._key(victim.tokens))
            if self.tracer is not None:
                self.tracer.instant("PREFIX_EVICT", cat="lifecycle",
                                    pages=len(victim.pages),
                                    tokens=len(victim.tokens))
        if self.metrics is not None and freed:
            self._c_evict.inc(freed)
        self._observe()
        return freed

    def clear(self) -> int:
        """Drop every tree reference (shared pages survive in their other
        owners' hands).  Returns the number of page references released."""
        released = 0
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            self.alloc.free(nd.pages)
            released += len(nd.pages)
        self.root = _Node(np.zeros((0,), np.int64), [], None)
        self.n_pages = 0
        self._observe()
        return released

    # -- reporting --------------------------------------------------------

    def stats(self) -> dict:
        n_nodes, n_a1 = 0, 0
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            n_nodes += 1
            n_a1 += len(nd.a1)
        out = {"cached_pages": self.n_pages, "nodes": n_nodes,
               "a1_entries": n_a1, "max_pages": self.max_pages}
        if self.metrics is not None:
            h, m = self._c_hit.value, self._c_miss.value
            out.update({
                "hits": h, "misses": m,
                "hit_rate": h / max(h + m, 1),
                "inserted_pages": self._c_ins.value,
                "evicted_pages": self._c_evict.value,
                "hit_tokens": {
                    "p50": self._h_hit_tokens.percentile(50),
                    "p99": self._h_hit_tokens.percentile(99),
                    "mean": self._h_hit_tokens.mean,
                    "count": self._h_hit_tokens.count,
                },
            })
        return out
