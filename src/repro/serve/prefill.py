"""DEPRECATED shim (one release): the chunked-prefill program moved into
``serve/scheduler.py`` when prefill and decode were collapsed into the ONE
mixed-tick dispatch (``EngineConfig.mixed_ticks``) — a single jitted
(slots, prefill_chunk) program serves lanes at any phase, so a separate
prefill module no longer exists.  Import ``make_paged_step`` /
``pack_chunks`` / ``last_valid_logits`` from ``repro.serve.scheduler``.
"""
from __future__ import annotations

import warnings

from repro.serve.scheduler import (  # noqa: F401
    last_valid_logits,
    make_paged_step,
    pack_chunks,
)

warnings.warn(
    "repro.serve.prefill is deprecated: the chunked-prefill program is the "
    "mixed-tick program in repro.serve.scheduler (make_paged_step); this "
    "shim will be removed next release",
    DeprecationWarning, stacklevel=2)
