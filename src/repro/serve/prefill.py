"""Chunked **batched** prefill for the paged engine.

The seed engine teacher-forced prompts one token per engine tick — one jit
dispatch per prompt token, with every decode-phase request stalled behind
it.  Here a prefill tick jits ONE multi-token forward over a (B, chunk)
window: every prefilling request advances up to ``chunk`` positions per
dispatch, and since a decode tick is the same program at chunk == 1
(``model.paged_decode_step``), the engine compiles exactly two XLA programs
regardless of prompt raggedness — (B, chunk) and (B, 1).

Requests with fewer remaining tokens than the chunk width ride along with
``n_valid < chunk``; their padded lanes scatter to the scratch page and
their padded logits are never read.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ExecutionPlan, Phase
from repro.models import model as M
from repro.serve import sampling as SP


def make_paged_step(cfg, plan=None):
    """Jitted paged tick: (params, cache, tokens (B,C), pos (B,),
    n_valid (B,), block_tables (B,T), temps, top_ks, top_ps, seeds,
    sample_pos) -> (logits (B,C,V), next_tokens (B,), new_cache).

    ``plan`` is a typed ``core.plan.ExecutionPlan`` — the primary (and only
    non-deprecated) way to configure the dispatch; its phase is pinned to
    paged here.  ``plan.dual_branch`` selects the MHA||MLP branch-parallel
    block for the steady-state layers (fal/parallel-family connections;
    validated), overlapping each block's paged KV gather with its FFN off
    the cached per-slot first-attention signal.  One returned callable
    serves both engine phases: call it with C == chunk for prefill ticks
    and C == 1 for decode ticks (two traces, cached by shape).  Sampling is
    fused into the program (one dispatch per tick) and the cache buffers
    are donated, so page pools update in place instead of being copied
    every tick.
    """
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.PAGED)
    plan.validate(cfg)

    def step(params, cache, tokens, pos, n_valid, block_tables,
             temps, top_ks, top_ps, seeds, sample_pos):
        batch = {"tokens": tokens, "pos": pos, "n_valid": n_valid,
                 "block_tables": block_tables}
        logits, new_cache = M.paged_decode_step(params, cfg, batch, cache,
                                                plan)
        nxt = jax.vmap(SP.sample_one)(
            last_valid_logits(logits, n_valid), temps, top_ks, top_ps,
            seeds, sample_pos)
        return logits, nxt, new_cache

    return jax.jit(step, donate_argnums=(1,))


def last_valid_logits(logits, n_valid):
    """(B, C, V), (B,) -> (B, V): each request's logits at its last valid
    chunk lane (lane 0 for requests that sat out the tick)."""
    last = jnp.clip(n_valid - 1, 0, logits.shape[1] - 1)
    return jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]


def pack_chunks(token_lists, chunk, slots):
    """Host-side chunk packing: per-slot lists of pending context tokens ->
    (tokens (slots, chunk), n_valid (slots,)) numpy arrays.  Empty lists
    (decode-phase or idle slots) get n_valid == 0."""
    toks = np.zeros((slots, chunk), np.int32)
    n_valid = np.zeros((slots,), np.int32)
    for i, lst in enumerate(token_lists):
        n = min(len(lst), chunk)
        toks[i, :n] = lst[:n]
        n_valid[i] = n
    return toks, n_valid
