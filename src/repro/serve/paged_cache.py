"""Host-side paged-KV bookkeeping: fixed-size pages with REFCOUNTS, per-request
block tables with copy-on-write, alloc/share/free/fragmentation stats.

The device arrays live in the model cache (``model.init_paged_cache``); this
module owns WHICH physical page each logical block of each request maps to —
and, since the prefix cache (``serve/prefix_cache.py``) landed, HOW MANY
owners each page has:

* every allocated page carries a refcount (1 at ``alloc``); ``share`` adds
  an owner (a prefix-cache entry, or a request whose block table maps a
  cached prefix) and ``free`` removes one — a page returns to the free list
  only when its last owner lets go.  Freeing a page that is already free
  raises loudly: with sharing in play a double-free would silently hand the
  same page to two requests and corrupt both streams.
* ``BlockTable`` supports copy-on-write: a table may ``adopt`` shared pages
  (a prefix hit mapping cached KV into a new request), and before a lane
  writes into a block the engine asks ``first_shared_block`` — a shared
  page must first be replaced by a private copy (device rows copied via
  ``model.copy_paged_pages``) so the write can never leak into another
  sharer's history.

Page 0 is a scratch page owned by no request — masked lanes of padded
prefill chunks are redirected there (attention.paged_scatter), so it is
never handed out by the allocator.

``metrics`` (optional ``repro.obs.MetricsRegistry``) mirrors the bookkeeping
into the observability layer: ``pages_alloc_total`` / ``pages_free_total`` /
``pages_shared_total`` counters and ``pages_in_use`` / ``pages_shared``
gauges, so page pressure AND sharing show up next to the engine's latency
series.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


def pages_needed(n_tokens: int, page_size: int) -> int:
    return max(0, -(-n_tokens // page_size))


class PageAllocator:
    """Refcounted free-list allocator over pages 1..num_pages-1 (page 0 =
    scratch).  ``alloc`` hands out pages at refcount 1; ``share`` adds an
    owner; ``free`` removes one and recycles the page at refcount 0.
    ``_free`` and ``_ref`` are private — all consumers go through
    alloc/share/free (CI greps for direct access)."""

    def __init__(self, num_pages: int, page_size: int, metrics=None,
                 page_bytes: int = 0):
        assert num_pages >= 2, "need >= 1 allocatable page + scratch page 0"
        self.num_pages = num_pages
        self.page_size = page_size
        # HBM bytes one page costs across every layer's pools (quantized
        # engines: narrow K/V pages + fp32 scale rows); 0 = unknown.
        # Turns page pressure into byte pressure so capacity comparisons
        # across kv_dtype are apples-to-apples (requests per HBM byte)
        self.page_bytes = page_bytes
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._ref: Dict[int, int] = {}     # page -> owner count (allocated)
        self.n_allocs = 0
        self.n_frees = 0
        self.n_shares = 0
        self.peak_in_use = 0
        self.metrics = metrics

    def _observe(self):
        if self.metrics is None:
            return
        site = "serve/paged_cache.py"
        self.metrics.gauge("pages_in_use", unit="pages",
                           site=site).set(self.in_use)
        self.metrics.gauge("pages_shared", unit="pages",
                           site=site).set(self.shared_pages)
        if self.page_bytes:
            self.metrics.gauge("engine_kv_bytes_in_use", unit="bytes",
                               site=site).set(self.in_use * self.page_bytes)

    @property
    def capacity(self) -> int:
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - len(self._free)

    @property
    def shared_pages(self) -> int:
        """Pages with more than one owner (tree + tables, or table + table)."""
        return sum(1 for c in self._ref.values() if c > 1)

    def refcount(self, page: int) -> int:
        """Owner count of ``page`` (0 = free)."""
        return self._ref.get(page, 0)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages at refcount 1, or None if the pool can't cover them (no
        partial grabs)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for pg in pages:
            self._ref[pg] = 1
        self.n_allocs += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        if self.metrics is not None:
            self.metrics.counter("pages_alloc_total", unit="pages",
                                 site="serve/paged_cache.py").inc(n)
            self._observe()
        return pages

    def share(self, pages: List[int]) -> None:
        """Add an owner to each (already-allocated) page.  A prefix-cache
        entry and every request whose block table maps it each hold one
        reference; the page recycles only when the last one frees."""
        for pg in pages:
            if pg not in self._ref:
                raise RuntimeError(
                    f"share of free page {pg}: only allocated pages can gain "
                    f"owners")
            self._ref[pg] += 1
        self.n_shares += len(pages)
        if self.metrics is not None:
            self.metrics.counter("pages_shared_total", unit="pages",
                                 site="serve/paged_cache.py").inc(len(pages))
            self._observe()

    def free(self, pages: List[int]) -> None:
        """Drop one owner per page; recycle at refcount 0.  Freeing a page
        that is already free raises: under refcounted sharing a double-free
        would hand the same page to two requests (silent KV corruption), so
        the allocator fails loudly instead."""
        for pg in pages:
            assert 0 < pg < self.num_pages, pg
            if pg not in self._ref:
                raise RuntimeError(
                    f"double free of page {pg}: page is not allocated "
                    f"(refcounted sharing would silently corrupt KV)")
        for pg in pages:
            self._ref[pg] -= 1
            if self._ref[pg] == 0:
                del self._ref[pg]
                self._free.append(pg)
        self.n_frees += len(pages)
        if self.metrics is not None:
            self.metrics.counter("pages_free_total", unit="pages",
                                 site="serve/paged_cache.py").inc(len(pages))
            self._observe()

    def stats(self) -> dict:
        return {
            "capacity": self.capacity,
            "in_use": self.in_use,
            "free": self.free_pages,
            "shared": self.shared_pages,
            "peak_in_use": self.peak_in_use,
            "allocs": self.n_allocs,
            "frees": self.n_frees,
            "shares": self.n_shares,
            "utilization": self.in_use / max(self.capacity, 1),
            "page_bytes": self.page_bytes,
            "bytes_in_use": self.in_use * self.page_bytes,
            "peak_bytes_in_use": self.peak_in_use * self.page_bytes,
        }


class BlockTable:
    """Per-request logical-block -> physical-page map with COW support.

    A table's pages come from two sources: private pages it allocated
    (``ensure``) and shared pages it adopted from the prefix cache
    (``adopt`` — the caller holds the extra refcount before handing them
    over).  ``release`` drops one reference per page either way; shared
    pages survive in their other owners' hands.  Before a lane writes into
    a block, the engine must confirm the page is private
    (``first_shared_block`` returns None) — a shared page is first
    replaced by a private device copy (copy-on-write)."""

    def __init__(self, allocator: PageAllocator, max_blocks: int):
        self.alloc = allocator
        self.max_blocks = max_blocks
        self.pages: List[int] = []

    def adopt(self, pages: List[int]) -> None:
        """Seed a fresh table with already-shared pages (the caller bumped
        their refcounts via ``allocator.share``; this table now owns those
        references and ``release`` will drop them)."""
        assert not self.pages, "adopt only seeds an empty table"
        self.pages = list(pages)

    def ensure(self, seq_len: int) -> bool:
        """Grow to cover ``seq_len`` tokens.  All-or-nothing: on failure the
        table is unchanged and the caller decides (preempt / queue)."""
        need = pages_needed(seq_len, self.alloc.page_size)
        if need > self.max_blocks:
            return False
        grow = need - len(self.pages)
        if grow <= 0:
            return True
        got = self.alloc.alloc(grow)
        if got is None:
            return False
        self.pages.extend(got)
        return True

    def first_shared_block(self, start_tok: int, end_tok: int) -> Optional[int]:
        """First block index in the token write range [start_tok, end_tok)
        whose page has other owners (refcount > 1) — the COW trigger: the
        engine copies that page's device KV rows to a fresh page and swaps
        the entry before any write lands.  None = whole range is private."""
        if end_tok <= start_tok:
            return None
        ps = self.alloc.page_size
        for blk in range(start_tok // ps, (end_tok - 1) // ps + 1):
            if blk < len(self.pages) and self.alloc.refcount(
                    self.pages[blk]) > 1:
                return blk
        return None

    def replace(self, blk: int, new_page: int) -> int:
        """Swap block ``blk``'s entry for ``new_page`` (the COW copy),
        dropping this table's reference on the old page.  Returns the old
        page (still owned by its remaining sharers)."""
        old = self.pages[blk]
        self.pages[blk] = new_page
        self.alloc.free([old])
        return old

    def shrink(self, seq_len: int) -> int:
        """Drop trailing pages beyond what ``seq_len`` tokens need — the
        speculative-decode rollback: a tick that grew the table for n
        proposed tokens but accepted fewer rewinds the growth here.  Only
        THIS table's references are dropped (free decrements refcounts),
        so pages still owned by the prefix cache or another sharer
        survive untouched.  Returns the number of references dropped."""
        keep = pages_needed(seq_len, self.alloc.page_size)
        tail = self.pages[keep:]
        if tail:
            self.pages = self.pages[:keep]
            self.alloc.free(tail)
        return len(tail)

    def release(self) -> None:
        if self.pages:
            self.alloc.free(self.pages)
            self.pages = []

    def as_row(self, width: Optional[int] = None) -> np.ndarray:
        """Padded int32 row for the device block-table tensor (pad = scratch
        page 0; positions there are never read thanks to the seq-len mask)."""
        width = self.max_blocks if width is None else width
        row = np.zeros((width,), np.int32)
        row[:len(self.pages)] = self.pages
        return row

    def internal_fragmentation(self, seq_len: int) -> int:
        """Allocated-but-unused KV slots (the defrag metric: pages are fixed
        size, so the only fragmentation a paged cache suffers is the unused
        tail of each request's last page)."""
        return len(self.pages) * self.alloc.page_size - seq_len
