"""Host-side paged-KV bookkeeping: fixed-size pages, per-request block
tables, alloc/free/fragmentation stats.

The device arrays live in the model cache (``model.init_paged_cache``); this
module owns WHICH physical page each logical block of each request maps to.
Page 0 is a scratch page owned by no request — masked lanes of padded
prefill chunks are redirected there (attention.paged_scatter), so it is
never handed out by the allocator.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np


def pages_needed(n_tokens: int, page_size: int) -> int:
    return max(0, -(-n_tokens // page_size))


class PageAllocator:
    """Free-list allocator over pages 1..num_pages-1 (page 0 = scratch).

    ``metrics`` (optional ``repro.obs.MetricsRegistry``) mirrors the
    bookkeeping into the observability layer: ``pages_alloc_total`` /
    ``pages_free_total`` counters and a ``pages_in_use`` gauge, so page
    pressure shows up next to the engine's latency series."""

    def __init__(self, num_pages: int, page_size: int, metrics=None):
        assert num_pages >= 2, "need >= 1 allocatable page + scratch page 0"
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self.n_allocs = 0
        self.n_frees = 0
        self.peak_in_use = 0
        self.metrics = metrics

    def _observe(self):
        if self.metrics is None:
            return
        site = "serve/paged_cache.py"
        self.metrics.gauge("pages_in_use", unit="pages",
                           site=site).set(self.in_use)

    @property
    def capacity(self) -> int:
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages, or None if the pool can't cover them (no partial grabs)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.n_allocs += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        if self.metrics is not None:
            self.metrics.counter("pages_alloc_total", unit="pages",
                                 site="serve/paged_cache.py").inc(n)
            self._observe()
        return pages

    def free(self, pages: List[int]) -> None:
        for pg in pages:
            assert 0 < pg < self.num_pages, pg
        self._free.extend(pages)
        self.n_frees += len(pages)
        if self.metrics is not None:
            self.metrics.counter("pages_free_total", unit="pages",
                                 site="serve/paged_cache.py").inc(len(pages))
            self._observe()

    def stats(self) -> dict:
        return {
            "capacity": self.capacity,
            "in_use": self.in_use,
            "free": self.free_pages,
            "peak_in_use": self.peak_in_use,
            "allocs": self.n_allocs,
            "frees": self.n_frees,
            "utilization": self.in_use / max(self.capacity, 1),
        }


class BlockTable:
    """Per-request logical-block -> physical-page map."""

    def __init__(self, allocator: PageAllocator, max_blocks: int):
        self.alloc = allocator
        self.max_blocks = max_blocks
        self.pages: List[int] = []

    def ensure(self, seq_len: int) -> bool:
        """Grow to cover ``seq_len`` tokens.  All-or-nothing: on failure the
        table is unchanged and the caller decides (preempt / queue)."""
        need = pages_needed(seq_len, self.alloc.page_size)
        if need > self.max_blocks:
            return False
        grow = need - len(self.pages)
        if grow <= 0:
            return True
        got = self.alloc.alloc(grow)
        if got is None:
            return False
        self.pages.extend(got)
        return True

    def release(self) -> None:
        if self.pages:
            self.alloc.free(self.pages)
            self.pages = []

    def as_row(self, width: Optional[int] = None) -> np.ndarray:
        """Padded int32 row for the device block-table tensor (pad = scratch
        page 0; positions there are never read thanks to the seq-len mask)."""
        width = self.max_blocks if width is None else width
        row = np.zeros((width,), np.int32)
        row[:len(self.pages)] = self.pages
        return row

    def internal_fragmentation(self, seq_len: int) -> int:
        """Allocated-but-unused KV slots (the defrag metric: pages are fixed
        size, so the only fragmentation a paged cache suffers is the unused
        tail of each request's last page)."""
        return len(self.pages) * self.alloc.page_size - seq_len
