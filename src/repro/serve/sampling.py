"""Token sampling for the serving engine: greedy / temperature / top-k /
top-p, seeded per request.

One jitted kernel samples the whole batch with per-request parameters
(temperature == 0 -> greedy; top_k == 0 and top_p >= 1 -> disabled), so
heterogeneous sampling configs share a single dispatch per tick.  Keys are
derived as ``fold_in(PRNGKey(seed), position)`` — a pure function of
(request seed, token position) — which makes generation replayable: a
preempted request that re-prefills its context and resumes sampling at the
same positions draws the same tokens.

Two interchangeable programs sample a lane: the reference ``sample_one``
(two full-vocab stable sorts — handles any (top_k, top_p) combination) and
``fast_sampler`` (one ``lax.top_k`` over ``TOPK_FAST_CAP`` candidates,
bit-exact whenever ``fast_eligible`` holds).  The engine picks the variant
host-side per tick, which matters most for speculative ticks where the
sampler runs once per draft proposal plus once per (lane, proposal) verify
cell.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0          # 0 -> greedy (argmax)
    top_k: int = 0                    # 0 -> disabled
    top_p: float = 1.0                # >= 1 -> disabled
    seed: int = 0

    @staticmethod
    def greedy() -> "SamplingParams":
        return SamplingParams()


def _mask_top_k(logits, k):
    """Keep EXACTLY the k highest logits (k <= 0 disables).

    Exact sorted-prefix semantics: a token survives iff its rank in the
    stable descending sort is < k, so threshold ties keep only enough of
    the tied tokens to total k (ties break toward the lower vocab index —
    the stable-sort order).  A ``logits >= thr`` comparison would instead
    keep EVERY token tied at the threshold, inflating the candidate set
    past k on tied/degenerate distributions."""
    V = logits.shape[-1]
    kk = jnp.where(k <= 0, V, jnp.clip(k, 1, V))
    order = jnp.argsort(-logits)               # stable: ties by vocab index
    rank = jnp.zeros((V,), jnp.int32).at[order].set(jnp.arange(V, dtype=jnp.int32))
    return jnp.where(rank < kk, logits, -jnp.inf)


def _mask_top_p(logits, p):
    """Nucleus: keep the SMALLEST prefix of the stable descending sort
    whose mass reaches p (p >= 1 disables).

    Exact sorted-prefix semantics: sorted token j survives iff the mass
    strictly before it is < p (the prefix stops at the first token whose
    inclusive mass reaches p; the top token always survives).  A
    threshold-value comparison (``probs >= thr``) would instead keep every
    token tied with the boundary probability, inflating the kept mass past
    p on tied distributions."""
    V = logits.shape[-1]
    probs = jax.nn.softmax(logits)
    order = jnp.argsort(-probs)                # stable: ties by vocab index
    sp = probs[order]
    cs = jnp.cumsum(sp)
    keep_sorted = (cs - sp) < p                # exclusive prefix mass < p
    keep_sorted = keep_sorted.at[0].set(True)  # never empty (p == 0 -> top-1)
    keep = jnp.zeros((V,), bool).at[order].set(keep_sorted)
    return jnp.where(keep | (p >= 1.0), logits, -jnp.inf)


def _sample_one(logits, temp, top_k, top_p, seed, pos):
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, -1)
    lg = _mask_top_k(logits, top_k)
    lg = _mask_top_p(lg, top_p)
    lg = lg / jnp.maximum(temp, 1e-6)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
    g = -jnp.log(-jnp.log(jax.random.uniform(
        key, logits.shape, minval=1e-20, maxval=1.0)))
    sampled = jnp.argmax(lg + g, -1)
    return jnp.where(temp <= 0.0, greedy, sampled).astype(jnp.int32)


# one request; composable into larger jitted programs (serve/scheduler.py)
sample_one = _sample_one

# sample_tokens(logits (B,V), temps (B,), top_ks (B,), top_ps (B,),
#               seeds (B,), positions (B,)) -> (B,) int32
sample_tokens = jax.jit(jax.vmap(_sample_one))


# --------------------------------------------------------------------------- #
# fast path: partial top-k selection instead of two full-vocab sorts
# --------------------------------------------------------------------------- #
# largest per-lane top_k the fast sampler handles exactly; lanes above it
# (or with top_k disabled while top_p is active) need the full-vocab sort
TOPK_FAST_CAP = 64


def fast_eligible(sp: SamplingParams, vocab, k_cap=TOPK_FAST_CAP):
    """True when ``fast_sampler`` reproduces the reference ``sample_one``
    exactly for this request: greedy lanes ignore the masks entirely, and
    a lane with ``1 <= top_k <= k_cap`` has BOTH masks contained in the
    top-k candidate set (top-p prunes within the top-k survivors)."""
    return sp.temperature <= 0.0 or 0 < sp.top_k <= min(k_cap, vocab)


def fast_sampler(vocab, k_cap=TOPK_FAST_CAP):
    """Build a ``sample_one`` drop-in that replaces the two full-vocab
    argsorts with one ``lax.top_k`` over ``k_cap`` candidates — ~20x
    cheaper per lane on the CPU fallback, which matters because the
    speculative tick samples (n-1) draft proposals plus an (S, n) target
    grid every dispatch.

    Bit-exact with the reference for every lane satisfying
    ``fast_eligible``: ``lax.top_k`` breaks ties toward the lower vocab
    index — the same order as the reference's stable descending argsort —
    so the kept set matches ``_mask_top_k``/``_mask_top_p`` exactly, and
    the gumbel noise is drawn over the FULL vocab with the same
    ``fold_in(seed, position)`` key and gathered onto the candidates, so
    the sampled token equals the reference's argmax over the masked
    vocab.  The engine checks eligibility host-side per tick and falls
    back to the reference program otherwise (still one dispatch)."""
    cap = int(min(k_cap, vocab))

    def sample(logits, temp, top_k, top_p, seed, pos):
        logits = logits.astype(jnp.float32)
        greedy = jnp.argmax(logits, -1)
        kk = jnp.clip(top_k, 1, cap)
        vals, idx = jax.lax.top_k(logits, cap)   # ties: lower index first
        in_k = jnp.arange(cap) < kk
        sv = jnp.where(in_k, vals, -jnp.inf)
        sp = jax.nn.softmax(sv)                  # mass over the survivors
        cs = jnp.cumsum(sp)
        keep = in_k & (((cs - sp) < top_p) | (top_p >= 1.0))
        keep = keep.at[0].set(True)              # never empty (p == 0)
        lg = jnp.where(keep, sv, -jnp.inf) / jnp.maximum(temp, 1e-6)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
        g = -jnp.log(-jnp.log(jax.random.uniform(
            key, logits.shape, minval=1e-20, maxval=1.0)))
        sampled = idx[jnp.argmax(lg + g[idx], -1)]
        return jnp.where(temp <= 0.0, greedy, sampled).astype(jnp.int32)

    return sample
