"""Token sampling for the serving engine: greedy / temperature / top-k /
top-p, seeded per request.

One jitted kernel samples the whole batch with per-request parameters
(temperature == 0 -> greedy; top_k == 0 and top_p >= 1 -> disabled), so
heterogeneous sampling configs share a single dispatch per tick.  Keys are
derived as ``fold_in(PRNGKey(seed), position)`` — a pure function of
(request seed, token position) — which makes generation replayable: a
preempted request that re-prefills its context and resumes sampling at the
same positions draws the same tokens.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0          # 0 -> greedy (argmax)
    top_k: int = 0                    # 0 -> disabled
    top_p: float = 1.0                # >= 1 -> disabled
    seed: int = 0

    @staticmethod
    def greedy() -> "SamplingParams":
        return SamplingParams()


def _mask_top_k(logits, k):
    """Keep the k highest logits (k <= 0 disables)."""
    V = logits.shape[-1]
    srt = jnp.sort(logits)[::-1]
    kk = jnp.where(k <= 0, V, k)
    thr = srt[jnp.clip(kk - 1, 0, V - 1)]
    return jnp.where(logits >= thr, logits, -jnp.inf)

def _mask_top_p(logits, p):
    """Nucleus: keep the smallest prefix of the sorted distribution with
    mass >= p (p >= 1 disables)."""
    probs = jax.nn.softmax(logits)
    sp = jnp.sort(probs)[::-1]
    cs = jnp.cumsum(sp)
    idx = jnp.argmax(cs >= p)            # first sorted index reaching mass p
    thr = sp[idx]
    keep = (probs >= thr) | (p >= 1.0)
    return jnp.where(keep, logits, -jnp.inf)


def _sample_one(logits, temp, top_k, top_p, seed, pos):
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, -1)
    lg = _mask_top_k(logits, top_k)
    lg = _mask_top_p(lg, top_p)
    lg = lg / jnp.maximum(temp, 1e-6)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
    g = -jnp.log(-jnp.log(jax.random.uniform(
        key, logits.shape, minval=1e-20, maxval=1.0)))
    sampled = jnp.argmax(lg + g, -1)
    return jnp.where(temp <= 0.0, greedy, sampled).astype(jnp.int32)


# one request; composable into larger jitted programs (serve/scheduler.py)
sample_one = _sample_one

# sample_tokens(logits (B,V), temps (B,), top_ks (B,), top_ps (B,),
#               seeds (B,), positions (B,)) -> (B,) int32
sample_tokens = jax.jit(jax.vmap(_sample_one))
