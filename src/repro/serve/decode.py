"""Serving runtime: batched KV-cache decode with a simple continuous-batching
request scheduler.

``make_serve_step`` builds the jitted one-token step used by the decode
dry-run shapes (decode_32k / long_500k): ONE new token against a
``seq_len``-deep cache.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ExecutionPlan, Phase
from repro.models import model as M
from repro.obs.metrics import MetricsRegistry


def make_serve_step(cfg, plan=None, dual_branch=False):
    """serve_step(params, cache, tokens (B,1), pos (B,)) ->
    (next_token (B,), logits, new_cache).

    ``plan`` is a typed ``core.plan.ExecutionPlan`` — the primary interface;
    its phase is pinned to decode here.  ``dual_branch=True`` (or a plan
    with ``dual_branch`` already set) runs the steady-state blocks with the
    MHA||MLP branch-parallel dispatch — valid only for connections whose
    MLP input is independent of the block's own attention (fal/parallel
    family; ``plan.validate`` rejects the rest loudly)."""
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.DECODE)
    if dual_branch:
        plan = plan.with_dual_branch()
    plan.validate(cfg)

    def serve_step(params, cache, tokens, pos):
        batch = {"tokens": tokens, "pos": pos}
        logits, new_cache = M.decode_step(params, cfg, batch, cache, plan)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, logits, new_cache

    return serve_step


def make_prefill_then_decode(cfg, plan=None):
    """Prefill via repeated decode steps (teacher-forcing the prompt into the
    cache) then greedy decode.  Used by examples/serve_requests.py."""
    serve_step = jax.jit(make_serve_step(cfg, plan))

    def generate(params, prompts: np.ndarray, max_new: int, cache):
        B, P = prompts.shape
        toks = jnp.asarray(prompts, jnp.int32)
        out = []
        nxt = toks[:, 0]
        for t in range(P + max_new - 1):
            cur = toks[:, t:t + 1] if t < P else nxt[:, None]
            pos = jnp.full((B,), t, jnp.int32)
            nxt, _, cache = serve_step(params, cache, cur, pos)
            if t >= P - 1:
                out.append(np.asarray(nxt))
        return np.stack(out, 1), cache

    return generate


# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,)
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    pos: int = 0
    done: bool = False


class ContinuousBatcher:
    """Slot-based continuous batching: fixed B slots; finished requests are
    replaced by queued ones.  Per-slot positions => the per-request ``pos``
    vector the decode kernels consume.

    The seed engine is single-program too — ONE (B, 1) dispatch per tick —
    but every lane advances exactly one token, so prompts prefill one
    dispatch per token.  The paged engine's packed tick keeps the
    one-dispatch-per-tick property while letting prefilling lanes pack a
    whole chunk of tokens into the flat budget; ``stats()`` reports the same ``dispatches_per_tick`` /
    occupancy fields on both engines (both routed through a
    ``repro.obs.MetricsRegistry``) so the comparison is direct."""

    def __init__(self, cfg, params, batch_slots: int, max_seq: int,
                 cache_dtype="float32", plan=None, dual_branch=False,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg, self.params = cfg, params
        self.plan = ExecutionPlan.resolve(plan).with_phase(Phase.DECODE)
        if dual_branch:
            self.plan = self.plan.with_dual_branch()
        self.B = batch_slots
        self.max_seq = max_seq
        self.cache = M.init_cache(cfg, batch_slots, max_seq, cache_dtype)
        self.serve_step = jax.jit(make_serve_step(cfg, self.plan))
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        site = "serve/decode.py"
        self._c_ticks = self.metrics.counter(
            "batcher_ticks_total", unit="ticks", site=site)
        self._c_dispatches = self.metrics.counter(
            "batcher_dispatches_total", unit="calls", site=site)
        self._h_occ = self.metrics.histogram(
            "batcher_occupancy", unit="ratio", site=site)

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.pop(0)

    def step(self):
        """One engine tick: feed each active slot its next token."""
        self._fill_slots()
        self._c_ticks.inc()
        self._c_dispatches.inc()
        self._h_occ.record(sum(r is not None for r in self.slots) / self.B)
        toks = np.zeros((self.B, 1), np.int32)
        pos = np.zeros((self.B,), np.int32)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if r.pos < len(r.prompt):
                toks[i, 0] = r.prompt[r.pos]
            else:
                toks[i, 0] = r.generated[-1]
            pos[i] = r.pos
        nxt, _, self.cache = self.serve_step(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos))
        nxt = np.asarray(nxt)
        finished = []
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            r.pos += 1
            if r.pos >= len(r.prompt):
                r.generated.append(int(nxt[i]))
            if len(r.generated) >= r.max_new or r.pos >= self.max_seq - 1:
                r.done = True
                finished.append(r)
                self.slots[i] = None
        return finished

    def run(self):
        done = []
        while any(s is not None for s in self.slots) or self.queue:
            done += self.step()
        return done

    def reset_stats(self):
        self.metrics.reset()

    @property
    def ticks(self) -> int:
        return self._c_ticks.value

    @property
    def dispatches(self) -> int:
        return self._c_dispatches.value

    def stats(self) -> dict:
        return {
            "ticks": self.ticks,
            "dispatches": self.dispatches,
            "dispatches_per_tick": self.dispatches / max(self.ticks, 1),
            "mean_occupancy": self._h_occ.mean,
            "metrics": self.metrics.to_dict(),
        }
