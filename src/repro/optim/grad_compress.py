"""Lossy gradient-compression baselines from the paper's Fig 7 comparison,
plus the compressed-collective hooks the explicit-TP stack routes its
gradient reductions through (``ExecutionPlan.grad_compress``).

* Grad-Q  [QSGD, ref 36]: per-tensor stochastic-free int8 quantisation of the
  gradients (quantise -> dequantise models the communication payload).
* Grad-LR [PowerSGD, ref 37]: rank-r approximation of 2-D gradients via a
  fixed random projection (one power-iteration step).

Both are *lossy* — the paper's point is that FAL removes communication
structurally, without touching gradient fidelity.  bench_comm.py compares
the quality hit.

Compressed collectives
======================

``compressed_psum`` / ``compressed_psum_scatter`` are ``custom_vjp``
wrappers around the explicit-TP collectives in ``models/blocks.py``.  The
FORWARD collective stays exact (serving and eval numerics are untouched);
only the BACKWARD cotangent reduction — the TP *gradient* all-reduce that
JAX emits as the transpose of each forward psum — is rerouted through a
compressed exchange:

* ``int8``   — two-phase QSGD all-reduce: the cotangent is split into tp
  row chunks, each chunk int8-quantised against its own fp32 amax scale and
  exchanged via ``all_to_all`` (the reduce-scatter phase), the locally
  summed shard re-quantised and ``all_gather``-ed back.  Wire payload is
  ~2n int8 bytes per device vs ~8n·(tp-1)/tp for the fp32 ring all-reduce
  (~4x fewer gradient bytes; ``bench_comm --json`` measures it off lowered
  HLO as ``grad_payload_bytes``).
* ``lowrank`` — PowerSGD: the (B, S, D) cotangent is reshaped to (B·S, D)
  and the *summed* gradient approximated as Q(QᵀΣg) with two rank-r
  all-reduces ((m, r) and (r, D)) instead of one (m, D) — one power
  iteration against a fixed random projection, matching ``lowrank`` above.

``method='none'`` never reaches these wrappers: ``blocks._assemble`` calls
``jax.lax.psum`` directly, so the default path lowers to byte-identical
HLO.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

GRAD_COMPRESS_METHODS = ("none", "int8", "lowrank")

_LOWRANK_RANK = 4


def quantize_int8(tree):
    def q(g):
        a = jnp.max(jnp.abs(g)) + 1e-12
        q8 = jnp.clip(jnp.round(g / a * 127), -127, 127).astype(jnp.int8)
        return q8.astype(g.dtype) * (a / 127)
    return jax.tree.map(q, tree)


def lowrank(tree, rank=4, seed=0):
    def lr(g):
        if g.ndim != 2 or min(g.shape) <= rank:
            return g
        key = jax.random.PRNGKey(seed + g.shape[0] * 131 + g.shape[1])
        omega = jax.random.normal(key, (g.shape[1], rank), g.dtype)
        p = g @ omega                       # (m, r)
        q, _ = jnp.linalg.qr(p)
        return q @ (q.T @ g)
    return jax.tree.map(lr, tree)


def compressed_bytes(tree, method, rank=4):
    """Communication payload estimate for the bench.

    Bytes follow each tensor's OWN dtype (``g.dtype.itemsize``), not an
    assumed 4; ``lowrank`` bills the factored (m + n)·r payload only for
    the 2-D matrices ``lowrank()`` actually compresses — tensors it skips
    (``ndim != 2`` or ``min(shape) <= rank``) ship uncompressed and are
    billed as such."""
    total = 0
    for g in jax.tree.leaves(tree):
        itemsize = jnp.dtype(g.dtype).itemsize
        if method == "none":
            total += g.size * itemsize
        elif method == "int8":
            total += g.size * 1 + 4          # int8 payload + one fp32 scale
        elif method == "lowrank":
            if g.ndim == 2 and min(g.shape) > rank:
                total += (g.shape[0] + g.shape[1]) * rank * itemsize
            else:
                total += g.size * itemsize   # lowrank() skips -> ships raw
    return total


# --------------------------------------------------------------------------- #
# compressed backward collectives (ExecutionPlan.grad_compress)
# --------------------------------------------------------------------------- #
def _int8_allreduce(ct, axis):
    """Two-phase QSGD all-reduce of a cotangent over mesh axis ``axis``:
    per-chunk int8 quantise -> all_to_all (reduce-scatter phase) -> local
    dequant + sum -> re-quantise the reduced shard -> int8 all_gather.
    Output is replicated, like ``jax.lax.psum``."""
    tp = jax.lax.psum(1, axis)               # static axis size
    flat = ct.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % tp
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(tp, -1)            # chunk j -> device j
    a = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) + 1e-12
    q8 = jnp.clip(jnp.round(chunks / a * 127), -127, 127).astype(jnp.int8)
    q8x = jax.lax.all_to_all(q8, axis, split_axis=0, concat_axis=0)
    ax = jax.lax.all_to_all(a, axis, split_axis=0, concat_axis=0)
    shard = jnp.sum(q8x.astype(ct.dtype) * (ax / 127), axis=0)  # (n/tp,)
    a2 = jnp.max(jnp.abs(shard)) + 1e-12
    q2 = jnp.clip(jnp.round(shard / a2 * 127), -127, 127).astype(jnp.int8)
    g8 = jax.lax.all_gather(q2, axis)        # (tp, n/tp) int8
    ga = jax.lax.all_gather(a2, axis)        # (tp,) fp32-ish
    out = (g8.astype(ct.dtype) * (ga[:, None] / 127)).reshape(-1)
    if pad:
        out = out[:n]
    return out.reshape(ct.shape)


def _lowrank_allreduce(ct, axis):
    """PowerSGD all-reduce: approximate the SUMMED cotangent as Q(QᵀΣg)
    with two rank-r all-reduces instead of one full-size one.  Falls back
    to the exact psum when the cotangent has no compressible 2-D shape."""
    r = _LOWRANK_RANK
    d = ct.shape[-1]
    m = ct.size // d
    if ct.ndim < 2 or min(m, d) <= r:
        return jax.lax.psum(ct, axis)
    g = ct.reshape(m, d)
    key = jax.random.PRNGKey(m * 131 + d)    # fixed projection, like lowrank()
    omega = jax.random.normal(key, (d, r), g.dtype)
    p = jax.lax.psum(g @ omega, axis)        # (m, r) — rank-r payload 1
    q, _ = jnp.linalg.qr(p)
    qtg = jax.lax.psum(q.T @ g, axis)        # (r, d) — rank-r payload 2
    return (q @ qtg).reshape(ct.shape)


def _compressed_allreduce(ct, axis, method):
    if method == "int8":
        return _int8_allreduce(ct, axis)
    if method == "lowrank":
        return _lowrank_allreduce(ct, axis)
    return jax.lax.psum(ct, axis)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def compressed_psum(x, axis, method):
    """``jax.lax.psum`` with a compressed BACKWARD collective: the forward
    all-reduce is exact; the cotangent reduction (the TP gradient
    all-reduce) runs ``method`` ∈ {'int8', 'lowrank'}.  Call sites use
    plain ``psum`` for method 'none' (byte-identical HLO)."""
    return jax.lax.psum(x, axis)


def _cpsum_fwd(x, axis, method):
    return jax.lax.psum(x, axis), None


def _cpsum_bwd(axis, method, _, ct):
    return (_compressed_allreduce(ct, axis, method),)


compressed_psum.defvjp(_cpsum_fwd, _cpsum_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def compressed_psum_scatter(x, axis, method):
    """Sequence-parallel ``psum_scatter`` (dimension 1, tiled — the SP
    blocks' layout) with a compressed BACKWARD all-gather: the cotangent
    shard is int8-quantised (or rank-r factored) before the gather that
    transposes the forward reduce-scatter."""
    return jax.lax.psum_scatter(x, axis, scatter_dimension=1, tiled=True)


def _cscatter_fwd(x, axis, method):
    return jax.lax.psum_scatter(x, axis, scatter_dimension=1,
                                tiled=True), None


def _int8_allgather(ct, axis):
    """int8 all-gather along dim 1 (tiled): quantise the local shard, ship
    int8 + one fp32 scale per device, dequantise after the gather."""
    a = jnp.max(jnp.abs(ct)) + 1e-12
    q8 = jnp.clip(jnp.round(ct / a * 127), -127, 127).astype(jnp.int8)
    g8 = jax.lax.all_gather(q8, axis, axis=1, tiled=True)
    ga = jax.lax.all_gather(a, axis)                   # (tp,)
    tp = ga.shape[0]
    shard = ct.shape[1]
    # scale stripe j covers the tiled gather's rows [j*shard, (j+1)*shard)
    scale = jnp.repeat(ga / 127, shard)
    shape = (1,) * 1 + (tp * shard,) + (1,) * (ct.ndim - 2)
    return g8.astype(ct.dtype) * scale.reshape(shape)


def _lowrank_allgather(ct, axis):
    """Rank-r all-gather: each device ships its shard's (m, r) + (r, d)
    PowerSGD factors; every device reconstructs all shards and re-tiles
    them along dim 1.  Exact gather when the shard is not compressible."""
    r = _LOWRANK_RANK
    d = ct.shape[-1]
    m = ct.size // d
    if ct.ndim < 2 or min(m, d) <= r:
        return jax.lax.all_gather(ct, axis, axis=1, tiled=True)
    g = ct.reshape(m, d)
    key = jax.random.PRNGKey(m * 131 + d)
    omega = jax.random.normal(key, (d, r), g.dtype)
    q, _ = jnp.linalg.qr(g @ omega)
    qtg = q.T @ g
    gq = jax.lax.all_gather(q, axis)                   # (tp, m, r)
    gt = jax.lax.all_gather(qtg, axis)                 # (tp, r, d)
    full = jnp.einsum("tmr,trd->tmd", gq, gt)          # (tp, m, d)
    tp = gq.shape[0]
    shard_shape = ct.shape
    out = full.reshape((tp,) + shard_shape)
    # stack of per-device shards -> tiled layout along dim 1
    perm = (1, 0) + tuple(range(2, out.ndim))
    out = out.transpose(perm)
    return out.reshape(shard_shape[:1] + (tp * shard_shape[1],)
                       + shard_shape[2:])


def _cscatter_bwd(axis, method, _, ct):
    if method == "int8":
        return (_int8_allgather(ct, axis),)
    if method == "lowrank":
        return (_lowrank_allgather(ct, axis),)
    return (jax.lax.all_gather(ct, axis, axis=1, tiled=True),)


compressed_psum_scatter.defvjp(_cscatter_fwd, _cscatter_bwd)
