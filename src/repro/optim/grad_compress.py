"""Lossy gradient-compression baselines from the paper's Fig 7 comparison:

* Grad-Q  [QSGD, ref 36]: per-tensor stochastic-free int8 quantisation of the
  gradients (quantise -> dequantise models the communication payload).
* Grad-LR [PowerSGD, ref 37]: rank-r approximation of 2-D gradients via a
  fixed random projection (one power-iteration step).

Both are *lossy* — the paper's point is that FAL removes communication
structurally, without touching gradient fidelity.  bench_comm.py compares
the quality hit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(tree):
    def q(g):
        a = jnp.max(jnp.abs(g)) + 1e-12
        q8 = jnp.clip(jnp.round(g / a * 127), -127, 127).astype(jnp.int8)
        return q8.astype(g.dtype) * (a / 127)
    return jax.tree.map(q, tree)


def lowrank(tree, rank=4, seed=0):
    def lr(g):
        if g.ndim != 2 or min(g.shape) <= rank:
            return g
        key = jax.random.PRNGKey(seed + g.shape[0] * 131 + g.shape[1])
        omega = jax.random.normal(key, (g.shape[1], rank), g.dtype)
        p = g @ omega                       # (m, r)
        q, _ = jnp.linalg.qr(p)
        return q @ (q.T @ g)
    return jax.tree.map(lr, tree)


def compressed_bytes(tree, method):
    """Communication payload estimate for the bench."""
    total = 0
    for g in jax.tree.leaves(tree):
        if method == "none":
            total += g.size * 4
        elif method == "int8":
            total += g.size * 1 + 4
        elif method == "lowrank":
            if g.ndim == 2:
                r = 4
                total += (g.shape[0] + g.shape[1]) * r * 4
            else:
                total += g.size * 4
    return total
