"""AdamW in pure JAX (pytree states) + gradient clipping + optional ZeRO-1
style optimizer-state sharding hints (the state mirrors the param tree, so
its PartitionSpec tree is derived the same way — launch/mesh.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable | float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.001
    clip_norm: float = 1.0
    state_dtype: str = "float32"   # bf16 for the 671B config (DESIGN.md §4)


def init_opt_state(params, ocfg: AdamWConfig):
    dt = jnp.dtype(ocfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decay_mask(path, p):
    """No weight decay on norms / biases / 1-d params."""
    return p.ndim >= 2


def adamw_update(params, grads, opt_state, ocfg: AdamWConfig):
    count = opt_state["count"] + 1
    lr = ocfg.lr(count) if callable(ocfg.lr) else ocfg.lr

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    bc1 = 1 - ocfg.b1 ** count.astype(jnp.float32)
    bc2 = 1 - ocfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = ocfg.b1 * m.astype(jnp.float32) + (1 - ocfg.b1) * g32
        v_new = ocfg.b2 * v.astype(jnp.float32) + (1 - ocfg.b2) * g32 * g32
        step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + ocfg.eps)
        if _decay_mask(None, p):
            step = step + ocfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm
