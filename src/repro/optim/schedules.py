"""LR schedules: cosine, one-cycle (paper Fig 9 / Cramming setting), and WSD
(warmup-stable-decay; minicpm-2b's native schedule, arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak, total_steps, warmup=0.01, floor=0.1):
    w = max(int(total_steps * warmup), 1)

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / w
        t = jnp.clip((step - w) / jnp.maximum(total_steps - w, 1), 0, 1)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < w, warm, cos)
    return f


def one_cycle(peak, total_steps, pct_up=0.3):
    up = max(int(total_steps * pct_up), 1)

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        rise = peak * step / up
        fall = peak * jnp.clip(1 - (step - up) / jnp.maximum(
            total_steps - up, 1), 0, 1)
        return jnp.where(step < up, rise, fall)
    return f


def wsd(peak, total_steps, warmup=0.05, decay=0.1, floor=0.1):
    """Warmup-Stable-Decay."""
    w = max(int(total_steps * warmup), 1)
    d_start = int(total_steps * (1 - decay))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / w
        t = jnp.clip((step - d_start) / jnp.maximum(total_steps - d_start, 1),
                     0, 1)
        dec = peak * (1 - (1 - floor) * t)
        return jnp.where(step < w, warm, jnp.where(step < d_start, peak, dec))
    return f
