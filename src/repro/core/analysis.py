"""Paper §3 motivation analyses: CKA similarity across blocks and gradient
magnitude of per-block MHA outputs.

These run on reduced DecoderLM configs with the layer stack *unrolled*
(params tree-sliced out of the scan stacks) so intermediate activations can
be captured and perturbed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fal
from repro.models import blocks as BL
from repro.models import layers as L
from repro.models import model as M


# ------------------------------------------------------------------------- #
def linear_cka(x, y):
    """Linear CKA between feature matrices (n, d1), (n, d2) [Kornblith'19]."""
    x = x - x.mean(0, keepdims=True)
    y = y - y.mean(0, keepdims=True)
    xty = x.T @ y
    num = jnp.sum(xty * xty)
    den = jnp.sqrt(jnp.sum((x.T @ x) ** 2)) * jnp.sqrt(jnp.sum((y.T @ y) ** 2))
    return num / jnp.maximum(den, 1e-12)


def _iter_layer_params(params, cfg):
    """Yield per-layer block params (unstacked) in depth order."""
    yield params["block0"], BL.window_schedule(cfg)[0], 0
    i = 1
    for name in ("blocks_dense", "blocks_moe"):
        if name in params and params[name] is not None:
            n = jax.tree.leaves(params[name])[0].shape[0]
            for j in range(n):
                pb = jax.tree.map(lambda a: a[j], params[name])
                yield pb, BL.window_schedule(cfg)[i], i
                i += 1


def collect_block_activations(params, cfg, batch):
    """Unrolled forward capturing per-block (mha_out, mlp_in, mlp_out, x).

    Returns dict of lists (length n_layers) of (B, S, D) arrays.
    Dense DecoderLM families only.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = M._embed_tokens(params, cfg, tokens, positions,
                        batch.get("image_embeds"))
    rec = {"mha_out": [], "mlp_in": [], "mlp_out": [], "x": []}
    a1_sig = None
    for pb, window, idx in _iter_layer_params(params, cfg):
        h = L.norm_apply(pb["ln1"], x, cfg.norm)
        from repro.models import attention as A
        a = A.gqa_apply(pb["attn"], cfg, h, positions, window=window)
        if idx == 0:
            mlp_in = fal.block0_mlp_input(cfg, pb, x, a)
            a1_sig = fal.first_attention_signal(cfg, pb, a)
        else:
            mlp_in = fal.mlp_input(cfg, pb, x, a, a1_sig)
        y = L.mlp_apply(pb["ffn"], mlp_in, cfg.mlp)
        rec["mha_out"].append(a)
        rec["mlp_in"].append(mlp_in)
        rec["mlp_out"].append(y)
        rec["x"].append(x)
        x = x + a + y
    rec["final"] = x
    return rec


def cka_table(params, cfg, batch):
    """Paper Fig 3(a): CKA similarity of consecutive blocks' MHA outputs,
    MLP inputs and MLP outputs."""
    rec = collect_block_activations(params, cfg, batch)
    out = {"mha_out": [], "mlp_in": [], "mlp_out": []}
    for k in out:
        seq = rec[k]
        for i in range(len(seq) - 1):
            a = seq[i].reshape(-1, seq[i].shape[-1]).astype(jnp.float32)
            b = seq[i + 1].reshape(-1, seq[i + 1].shape[-1]).astype(jnp.float32)
            out[k].append(float(linear_cka(a, b)))
    return out


def mha_gradient_magnitudes(params, cfg, batch):
    """Paper Fig 4(a): L1 norm of dLoss/d(MHA_i output) per block.

    Implemented by injecting zero perturbations eps_i at every block's MHA
    output and differentiating wrt them.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    layer_list = list(_iter_layer_params(params, cfg))
    eps0 = [jnp.zeros((B, S, cfg.d_model)) for _ in layer_list]

    def loss_with_eps(eps):
        x = M._embed_tokens(params, cfg, tokens, positions,
                            batch.get("image_embeds"))
        a1_sig = None
        from repro.models import attention as A
        for (pb, window, idx), e in zip(layer_list, eps):
            h = L.norm_apply(pb["ln1"], x, cfg.norm)
            a = A.gqa_apply(pb["attn"], cfg, h, positions, window=window) + e
            if idx == 0:
                mlp_in = fal.block0_mlp_input(cfg, pb, x, a)
                a1_sig = fal.first_attention_signal(cfg, pb, a)
            else:
                mlp_in = fal.mlp_input(cfg, pb, x, a, a1_sig)
            y = L.mlp_apply(pb["ffn"], mlp_in, cfg.mlp)
            x = x + a + y
        logits = M._logits(params, cfg, x)
        return M.cross_entropy(logits[:, :-1], tokens[:, 1:])

    grads = jax.grad(loss_with_eps)(eps0)
    return [float(jnp.sum(jnp.abs(g))) for g in grads]


def ablate_attention_perplexity(params, cfg, batch, drop_layer=None,
                                drop_connections=False, drop_all_mha=False):
    """Paper Fig 3(b)/4(b): perplexity with MHA layers or MHA->MLP
    connections removed."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = M._embed_tokens(params, cfg, tokens, positions,
                        batch.get("image_embeds"))
    a1_sig = None
    from repro.models import attention as A
    for pb, window, idx in _iter_layer_params(params, cfg):
        h = L.norm_apply(pb["ln1"], x, cfg.norm)
        a = A.gqa_apply(pb["attn"], cfg, h, positions, window=window)
        if drop_all_mha or (drop_layer is not None and idx == drop_layer):
            a = jnp.zeros_like(a)
        if idx == 0:
            mlp_in = fal.block0_mlp_input(cfg, pb, x, a)
            a1_sig = fal.first_attention_signal(cfg, pb, a)
        else:
            mlp_in = fal.mlp_input(cfg, pb, x, a, a1_sig)
        if drop_connections and cfg.connection == "preln":
            # remove the direct MHA->MLP connection: MLP sees ln2(x) only
            mlp_in = L.norm_apply(pb["ln2"], x, cfg.norm)
        y = L.mlp_apply(pb["ffn"], mlp_in, cfg.mlp)
        x = x + a + y
    logits = M._logits(params, cfg, x)
    ce = M.cross_entropy(logits[:, :-1], tokens[:, 1:])
    return float(jnp.exp(ce))
