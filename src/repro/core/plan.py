"""ExecutionPlan — the typed parallel-execution plan for the whole stack.

The paper's contribution is a *communication structure* (one fused MHA+MLP
all-reduce per FAL block instead of preln's two), and the structure a run
uses is a property of the whole program, not of one call site.  This module
makes that structure an explicit, validated object:

    plan = ExecutionPlan.from_mesh(mesh, tp="explicit", sp=True)
    plan.validate(cfg)                      # loud errors, before tracing
    model.forward(params, cfg, batch, plan)

replacing the stringly-typed context dict that used to thread through
model, train, launch, and serving code unvalidated (its one-release
``from_legacy_dict`` shim has expired and is gone — ``resolve`` now
rejects dicts loudly).

Plan axes:

* ``phase``  — train | eval | prefill | decode | paged.  What used to be
  the ``mode=`` string argument of ``model.forward`` / ``blocks.block_apply``
  and the serving engines.
* ``tp``     — none | gspmd | explicit.  ``explicit`` routes the decoder
  family through the shard_map partial-sum stack
  (``models/model.py::decoder_stack_tp``) realising the paper's per-block
  collective fork; ``gspmd`` lets XLA shard against ``launch/mesh.py``'s
  PartitionSpecs.
* ``sequence_parallel`` — Megatron-SP-style LN regions under explicit TP:
  inter-block activations stay sharded over the model axis along the
  sequence dimension; blocks pay reduce-scatter/all-gather pairs instead of
  all-reduces (same reduce-collective count, per-block reduce bytes cut by
  ``tp_size``; ``models/blocks.py``).
* ``dual_branch`` — decode-time MHA||MLP branch parallelism: steady-state
  blocks compute the MLP branch from the (cached) first-attention signal
  concurrently with the attention branch's KV gather instead of serially
  after it (``models/blocks.py::_block_apply_dual``; the paper's "parallel
  execution of MHA and MLP" claim at serving time).  Valid only for
  decode/paged phases and connection modes whose MLP input is independent
  of the block's own attention (``core.fal.DUAL_BRANCH_MODES``).
* ``grad_compress`` — none | int8 | lowrank.  Opt-in compressed BACKWARD
  collectives under explicit TP: the forward psum/psum_scatter structure
  is untouched, but each one's transpose — the TP *gradient* all-reduce /
  all-gather — runs through ``optim/grad_compress.py``'s QSGD-int8 or
  PowerSGD-low-rank exchange (``compressed_psum`` /
  ``compressed_psum_scatter``), cutting measured gradient payload bytes
  ~4x for int8 (``bench_comm --json`` → ``grad_payload_bytes``).  Lossy
  by design, like the Fig 7 baselines; 'none' lowers byte-identical HLO.

Inside the explicit-TP shard_map the blocks see ``plan.inner()`` — the same
plan with ``mesh=None`` and ``local_tp_size`` set; ``plan.tp_axis`` is then
the axis the partial-sum psums reduce over (None on replicated/GSPMD paths).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional, Tuple


class Phase(enum.Enum):
    """Execution phase — what used to be the ``mode=`` string."""
    TRAIN = "train"
    EVAL = "eval"
    PREFILL = "prefill"
    DECODE = "decode"
    PAGED = "paged"

    @classmethod
    def coerce(cls, v) -> "Phase":
        if isinstance(v, Phase):
            return v
        try:
            return cls(v)
        except ValueError:
            raise ValueError(
                f"unknown phase {v!r}; valid: "
                f"{[p.value for p in cls]}") from None


#: phases that run the full-sequence block path (vs KV-cache decode/paged)
FULL_SEQUENCE_PHASES = (Phase.TRAIN, Phase.EVAL, Phase.PREFILL)


class TPStyle(enum.Enum):
    """Tensor-parallel style."""
    NONE = "none"
    GSPMD = "gspmd"
    EXPLICIT = "explicit"

    @classmethod
    def coerce(cls, v) -> "TPStyle":
        if isinstance(v, TPStyle):
            return v
        if v is None:
            return cls.NONE
        try:
            return cls(v)
        except ValueError:
            raise ValueError(
                f"unknown TP style {v!r}; valid: "
                f"{[t.value for t in cls]}") from None


#: families with an explicit partial-sum TP stack (decoder_stack_tp)
EXPLICIT_TP_FAMILIES = ("dense", "moe", "vlm")

#: families whose decode path runs FAL transformer blocks and therefore has
#: a dual-branch (MHA||MLP) dispatch: the decoder family + the zamba hybrid
#: (its weight-shared attention block is a FAL block).  audio's decoder
#: blocks consume cross-attention (must assemble); ssm has no MHA/MLP fork.
DUAL_BRANCH_FAMILIES = ("dense", "moe", "vlm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Frozen description of how one program executes.

    ``mesh``/``data_axes``/``model_axis`` describe the device layout;
    ``local_tp_size`` is non-zero only on the plan a shard_map local body
    sees (``inner()``), where ``mesh`` is None by construction.
    """
    phase: Phase = Phase.TRAIN
    tp: TPStyle = TPStyle.NONE
    sequence_parallel: bool = False
    dual_branch: bool = False
    grad_compress: str = "none"            # none | int8 | lowrank
    mesh: Any = None                       # jax.sharding.Mesh | None
    data_axes: Tuple[str, ...] = ()
    model_axis: str = "model"
    local_tp_size: int = 0                 # set only by inner()

    # ------------------------------------------------------------- build --
    @classmethod
    def single_device(cls, phase=Phase.TRAIN,
                      dual_branch: bool = False) -> "ExecutionPlan":
        """Replicated single-program plan (no mesh, no TP)."""
        return cls(phase=Phase.coerce(phase), dual_branch=bool(dual_branch))

    @classmethod
    def from_mesh(cls, mesh, *, tp="gspmd", sp: bool = False,
                  phase=Phase.TRAIN, model_axis: str = "model",
                  data_axes: Optional[Tuple[str, ...]] = None,
                  dual_branch: bool = False,
                  grad_compress: str = "none") -> "ExecutionPlan":
        """Plan over ``mesh``.  ``data_axes`` defaults to every mesh axis
        except ``model_axis`` (so a ("pod", "data", "model") mesh composes
        pure DP across pods automatically)."""
        if data_axes is None:
            data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
        return cls(phase=Phase.coerce(phase), tp=TPStyle.coerce(tp),
                   sequence_parallel=bool(sp), mesh=mesh,
                   data_axes=tuple(data_axes), model_axis=model_axis,
                   dual_branch=bool(dual_branch),
                   grad_compress=str(grad_compress))

    @classmethod
    def resolve(cls, plan) -> "ExecutionPlan":
        """Entry-point coercion for every public API taking a plan.

        Accepts an ExecutionPlan, a Phase (or its string value — the old
        ``mode=`` calling convention), or None (single device, train).
        Context dicts (the pre-plan calling convention) are rejected
        loudly: their one-release ``from_legacy_dict`` shim has expired.
        """
        if isinstance(plan, ExecutionPlan):
            return plan
        if isinstance(plan, dict):
            raise TypeError(
                "context dicts are no longer accepted (the one-release "
                "shim expired); construct an ExecutionPlan (core.plan) — "
                "e.g. ExecutionPlan.from_mesh(mesh, tp='explicit')")
        phase = Phase.coerce(plan) if plan is not None else Phase.TRAIN
        return cls.single_device(phase)

    # -------------------------------------------------------- derived -----
    def with_phase(self, phase) -> "ExecutionPlan":
        return dataclasses.replace(self, phase=Phase.coerce(phase))

    def with_dual_branch(self, flag: bool = True) -> "ExecutionPlan":
        """Same plan with MHA||MLP decode branch parallelism toggled."""
        return dataclasses.replace(self, dual_branch=bool(flag))

    def with_grad_compress(self, method: str) -> "ExecutionPlan":
        """Same plan with compressed backward TP collectives selected."""
        return dataclasses.replace(self, grad_compress=str(method))

    def inner(self) -> "ExecutionPlan":
        """The plan a shard_map local body sees: no mesh (collectives are
        explicit inside), ``local_tp_size`` pinned to the model-axis size."""
        return dataclasses.replace(self, mesh=None,
                                   local_tp_size=self.tp_size)

    @property
    def tp_size(self) -> int:
        if self.local_tp_size:
            return self.local_tp_size
        if self.mesh is not None and self.model_axis in self.mesh.axis_names:
            return int(self.mesh.shape[self.model_axis])
        return 1

    @property
    def tp_axis(self) -> Optional[str]:
        """Mesh axis name the block kernels psum partial sums over — set
        only INSIDE the explicit-TP shard_map; None on replicated/GSPMD
        paths (``blocks._assemble`` is then the identity)."""
        return self.model_axis if self.local_tp_size else None

    @property
    def use_explicit_tp(self) -> bool:
        """True when the caller asked for the explicit partial-sum TP path
        (shard_map over the block stack) instead of implicit GSPMD."""
        return self.tp is TPStyle.EXPLICIT and self.mesh is not None

    @property
    def is_sharded(self) -> bool:
        return self.mesh is not None

    @property
    def full_sequence(self) -> bool:
        return self.phase in FULL_SEQUENCE_PHASES

    @property
    def is_training_like(self) -> bool:
        """Train/eval: loss-path execution (e.g. the sharded-MoE dispatch
        is worth its collectives; decode token counts are not)."""
        return self.phase in (Phase.TRAIN, Phase.EVAL)

    # -------------------------------------------------------- validate ----
    def validate(self, cfg) -> "ExecutionPlan":
        """Fail loudly — before any tracing — when the plan cannot execute
        ``cfg``.  Returns self so call sites can chain."""
        if self.sequence_parallel and self.tp is not TPStyle.EXPLICIT:
            raise ValueError(
                "sequence_parallel=True requires tp='explicit': SP shards "
                "inter-block activations inside the explicit partial-sum "
                "shard_map stack; there is no GSPMD/replicated SP path")
        if self.sequence_parallel and self.phase not in FULL_SEQUENCE_PHASES:
            raise ValueError(
                f"sequence_parallel=True is a full-sequence layout "
                f"(train/eval/prefill); phase={self.phase.value} decodes "
                f"single tokens against KV caches")
        if self.dual_branch:
            self._validate_dual_branch(cfg)
        if self.grad_compress not in ("none", "int8", "lowrank"):
            raise ValueError(
                f"unknown grad_compress {self.grad_compress!r}; valid: "
                f"none/int8/lowrank (optim/grad_compress.py methods)")
        if self.grad_compress != "none" and self.tp is not TPStyle.EXPLICIT:
            raise ValueError(
                "grad_compress != 'none' requires tp='explicit': the "
                "compressed collectives wrap the explicit-TP partial-sum "
                "psums (models/blocks.py); there is no GSPMD/replicated "
                "gradient-compression path")
        if self.tp is TPStyle.EXPLICIT:
            if self.mesh is None:
                raise ValueError("tp='explicit' requires a mesh (the "
                                 "explicit-TP stack shards over it)")
            if cfg.family not in EXPLICIT_TP_FAMILIES:
                raise ValueError(
                    f"tp='explicit': family '{cfg.family}' has no "
                    f"explicit-TP stack (decoder family only: "
                    f"{EXPLICIT_TP_FAMILIES}) — running it would silently "
                    f"fall back to GSPMD and mislabel any numbers")
            self._check_divisibility(cfg)
        if self.mesh is not None:
            names = tuple(self.mesh.axis_names)
            if self.model_axis not in names:
                raise ValueError(f"model_axis '{self.model_axis}' not in "
                                 f"mesh axes {names}")
            bad = [a for a in self.data_axes if a not in names]
            if bad:
                raise ValueError(f"data_axes {bad} not in mesh axes {names}")
        return self

    def _validate_dual_branch(self, cfg):
        """MHA||MLP branch parallelism exists only where the MLP input is
        independent of the block's own attention — fail loudly otherwise
        instead of silently running the sequential path and mislabeling any
        numbers collected under the plan."""
        from repro.core import fal  # core.fal pulls models.layers; keep lazy
        if self.phase not in (Phase.DECODE, Phase.PAGED):
            raise ValueError(
                f"dual_branch=True is a decode-time dispatch (decode/paged "
                f"phases); phase={self.phase.value} runs full-sequence "
                f"blocks whose collective structure is fixed by the "
                f"connection mode, not by branch scheduling")
        if cfg.family not in DUAL_BRANCH_FAMILIES:
            raise ValueError(
                f"dual_branch=True: family '{cfg.family}' has no MHA||MLP "
                f"decode dispatch ({DUAL_BRANCH_FAMILIES} only) — audio "
                f"decoder blocks consume cross-attention and ssm blocks "
                f"have no attention/MLP fork; running it would silently "
                f"fall back and mislabel any numbers")
        if cfg.connection not in fal.DUAL_BRANCH_MODES:
            raise ValueError(
                f"dual_branch=True requires a connection whose MLP input "
                f"is independent of the block's own attention "
                f"({'/'.join(fal.DUAL_BRANCH_MODES)}); "
                f"'{cfg.connection}' must assemble MHA output before the "
                f"MLP can start, so the branches cannot run concurrently")
        if cfg.post_norms:
            raise ValueError(
                "dual_branch=True: post_norms normalise the assembled "
                "attention output before the residual merge — the MLP "
                "branch cannot be issued concurrently with the KV gather")

    def _check_divisibility(self, cfg):
        """Explicit TP shards heads/hidden/experts evenly — fail loudly when
        the config doesn't divide (GSPMD pads; shard_map in_specs cannot)."""
        tp_size = self.tp_size

        def div(n, what):
            if n % tp_size:
                raise ValueError(f"explicit TP: {what}={n} is not divisible "
                                 f"by tp_size={tp_size}")
        div(cfg.n_heads, "n_heads")
        if not cfg.use_mla and cfg.n_kv_heads % tp_size \
                and tp_size % cfg.n_kv_heads:
            # n_kv_heads < tp_size is fine when groups align (KV
            # replication, attention._kv_group_slice); anything else cannot
            # shard evenly
            raise ValueError(f"explicit TP: n_kv_heads={cfg.n_kv_heads} "
                             f"divides neither way with tp_size={tp_size}")
        div(cfg.dense_d_ff or cfg.d_ff, "d_ff")
        if cfg.n_experts:
            div(cfg.n_experts, "n_experts")
            if cfg.n_shared_experts:
                div(cfg.moe_d_ff * cfg.n_shared_experts, "shared-expert d_ff")
