# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

# The typed execution plan is the package's public entry point for
# selecting phase / TP style / sequence parallelism (see core/plan.py).
from repro.core.plan import ExecutionPlan, Phase, TPStyle  # noqa: F401
