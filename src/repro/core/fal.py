"""FAL (First Attentions Last) — the paper's contribution as a composable
connection-mode module.

A transformer block is ``x + MHA(ln1(x)) + MLP(mlp_input)``; the paper's whole
technique is the choice of ``mlp_input``:

  preln     : ln2(x + a)                      -- baseline GPT (eq 1); MLP needs the
                                                 *complete* attention output -> TP
                                                 all-reduce between MHA and MLP
  parallel  : ln2(x)                          -- PaLM/GPT-J baseline; no dependency
  fal       : ln2(x) + ln_a(a1)               -- eq (2); a1 = first block's MHA out,
                                                 ln_a owned by block 1 (footnote 3)
  falplus   : ln2(x + a) + ln_fal_i(a1)       -- eq (7); per-block ln_fal, keeps the
                                                 direct connection (quality variant)
  ablation1 : ln2(x) + ln_fal_i(a)            -- Apdx D.1: latest attention in the
                                                 LN+LN form (shown worse than preln)
  ablation2 : block0 preln, later blocks MLP(ln2(x)) with no alternative signal
                                                 (Apdx D.1: ~baseline, worse than FAL)

``mlp_input_depends_on_local_attention(mode)`` is the property the TP runtime
keys on: when False, the block's MHA partial sum never needs to be assembled
before the MLP, so the per-block MHA all-reduce is fused into the MLP one
(2 -> 1 collectives per block).  Since the toy-stack retirement this predicate
drives the REAL model: ``models/blocks.py::block_apply`` consumes it (via
``attention_must_assemble``) to choose between the two-psum assembled path and
the paper's fused single-psum path whenever it runs inside the
``models/model.py::decoder_stack_tp`` shard_map; the replicated single-device
path is the same code with the assemble reduced over nothing (tp_size = 1).
"""
from __future__ import annotations

from repro.models import layers as L

# modes whose MLP input requires the *assembled* (post all-reduce) attention
# output of the SAME block:
_NEEDS_LOCAL_ATTN = {"preln": True, "parallel": False, "fal": False,
                     "falplus": True, "ablation1": True, "ablation2": False}

# modes with a per-block LN over the injected signal:
NEEDS_LN_FAL = {"falplus", "ablation1"}
# modes that consume the first block's attention output:
USES_FIRST_ATTENTION = {"fal", "falplus"}

#: modes whose steady-state MLP input is independent of the block's OWN
#: attention output — the property the decode-time MHA||MLP dual-branch
#: dispatch keys on (``ExecutionPlan(dual_branch=True)``): both branches can
#: be issued concurrently because the MLP reads only the residual stream and
#: the cached first-attention signal, never this block's KV gather.
DUAL_BRANCH_MODES = tuple(m for m, dep in _NEEDS_LOCAL_ATTN.items()
                          if not dep)  # ('parallel', 'fal', 'ablation2')


def mlp_input_depends_on_local_attention(mode: str) -> bool:
    return _NEEDS_LOCAL_ATTN[mode]


def attention_must_assemble(mode: str, is_block0: bool = False) -> bool:
    """True when the block's own MHA output must be fully assembled (post
    TP all-reduce) before its MLP input / signal export can be formed.

    Steady-state blocks: exactly ``mlp_input_depends_on_local_attention``.
    Block 0 additionally assembles for ``fal`` (it exports the LN'd
    first-attention signal — the single extra all-reduce of Fig 2, paid once
    for the whole depth) and for ``ablation2`` (its eq-4 direct connection);
    only ``parallel`` keeps block 0 fused.
    """
    if is_block0:
        return mode != "parallel"
    return _NEEDS_LOCAL_ATTN[mode]


def first_attention_signal(cfg, block0_params, a1_raw):
    """What block 1 exports to the rest of the depth.

    FAL: normalize ONCE in block 1 (``ln_a``, the repositioned LN of
    footnote 3) so later blocks reuse the cached tensor with zero recompute.
    FAL+: export the raw tensor; each block applies its own ``ln_fal``.
    """
    if cfg.connection == "fal":
        return L.norm_apply(block0_params["ln_a"], a1_raw, cfg.norm)
    if cfg.connection == "falplus":
        return a1_raw
    return None


def mlp_input(cfg, p, x, a, a1_sig, norm_kind=None):
    """Compute the MLP input for one block given mode; see module docstring.

    p: block params (ln2 always; ln_fal for falplus/ablation1).
    x: block input (residual stream);  a: this block's MHA output;
    a1_sig: output of ``first_attention_signal`` (None unless fal/falplus).
    """
    nk = norm_kind or cfg.norm
    mode = cfg.connection
    if mode == "preln":
        return L.norm_apply(p["ln2"], x + a, nk)
    if mode == "parallel" or mode == "ablation2":
        return L.norm_apply(p["ln2"], x, nk)
    if mode == "fal":
        return L.norm_apply(p["ln2"], x, nk) + a1_sig.astype(x.dtype)
    if mode == "falplus":
        return (L.norm_apply(p["ln2"], x + a, nk)
                + L.norm_apply(p["ln_fal"], a1_sig, nk).astype(x.dtype))
    if mode == "ablation1":
        return (L.norm_apply(p["ln2"], x, nk)
                + L.norm_apply(p["ln_fal"], a, nk))
    raise ValueError(mode)


def block0_mlp_input(cfg, p, x, a, norm_kind=None):
    """Block 1 ("preparation stage").  For FAL the repositioned ``ln_a`` is
    applied to the MHA output and the same tensor feeds block 1's own MLP:
    ``ln2(x) + ln_a(a)`` (eq 2 with i=1).  For ablation2 block 1 keeps its
    direct connection (eq 4).  Other modes behave as in later blocks."""
    nk = norm_kind or cfg.norm
    mode = cfg.connection
    if mode == "fal":
        return L.norm_apply(p["ln2"], x, nk) + L.norm_apply(p["ln_a"], a, nk)
    if mode == "ablation2":
        return L.norm_apply(p["ln2"], x + a, nk)
    if mode == "falplus":
        # eq (7) i=1 branch: LN(X_1 + MHA_1)  (no ln_fal on itself)
        return L.norm_apply(p["ln2"], x + a, nk)
    return mlp_input(cfg, p, x, a, None, nk) if mode in ("preln", "parallel") \
        else mlp_input(cfg, p, x, a, a, nk)
