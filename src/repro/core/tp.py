"""Explicit Megatron-style tensor parallelism via shard_map — the paper's
Fig 2 on a TPU mesh.

Per transformer block and direction:
  preln   : all-reduce(MHA partial) -> MLP -> all-reduce(MLP partial)   = 2
  fal     : MHA partial + MLP partial added LOCALLY -> one all-reduce   = 1
  parallel: same as fal (but no first-attention signal -> worse quality)

``count_collectives`` parses lowered HLO so tests/benches can assert the
halving structurally (no hardware needed).
"""
from __future__ import annotations

import re
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compat import shard_map
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


# ------------------------------------------------------------------------- #
def tp_block_init(key, d, d_ff, n_heads, dtype="float32"):
    ks = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(d)
    dt = jnp.dtype(dtype)
    return {
        "ln1": L.norm_init(d, "layernorm", dtype),
        "ln2": L.norm_init(d, "layernorm", dtype),
        "ln_a": L.norm_init(d, "layernorm", dtype),   # FAL footnote-3 LN
        # (3, d, d) so column-sharding the LAST dim keeps each shard's
        # q/k/v slices head-aligned (a flat (d, 3d) would interleave)
        "wqkv": jax.random.normal(ks[0], (3, d, d), dt) * s,
        "wo": jax.random.normal(ks[1], (d, d), dt) * s,
        "wi": jax.random.normal(ks[2], (d, d_ff), dt) * s,
        "wo2": jax.random.normal(ks[3], (d_ff, d), dt) / np.sqrt(d_ff),
    }


def _attn_local(p, h, n_heads_local, causal=True):
    """Local slice of MHA: wqkv column-sharded -> heads_local heads."""
    B, S, _ = h.shape
    w = p["wqkv"]
    q, k, v = h @ w[0], h @ w[1], h @ w[2]
    Dh = q.shape[-1] // n_heads_local
    q = q.reshape(B, S, n_heads_local, Dh)
    k = k.reshape(B, S, n_heads_local, Dh)
    v = v.reshape(B, S, n_heads_local, Dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (Dh ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    o = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    return o.reshape(B, S, -1) @ p["wo"]          # row-sharded wo -> PARTIAL sum


def _mlp_local(p, h):
    return jax.nn.gelu(h @ p["wi"]) @ p["wo2"]     # row-sharded wo2 -> PARTIAL


def tp_block_apply(p, x, a1n, *, mode, n_heads, tp_size, axis="model"):
    """Runs INSIDE shard_map.  x, a1n replicated; weights sharded on ``axis``.

    Returns (x_out, a1n_candidate).  The collective structure is the paper's
    contribution:  preln/falplus -> 2 psums;  fal/parallel -> 1 psum.
    """
    h = L.norm_apply(p["ln1"], x, "layernorm")
    a_partial = _attn_local(p, h, n_heads // tp_size)

    if mode in ("preln", "falplus"):
        a = jax.lax.psum(a_partial, axis)                       # all-reduce 1
        if mode == "preln":
            mlp_in = L.norm_apply(p["ln2"], x + a, "layernorm")
        else:
            mlp_in = (L.norm_apply(p["ln2"], x + a, "layernorm")
                      + L.norm_apply(p["ln_a"], a1n, "layernorm"))
        m = jax.lax.psum(_mlp_local(p, mlp_in), axis)           # all-reduce 2
        return x + a + m, a

    if mode in ("fal", "parallel"):
        mlp_in = L.norm_apply(p["ln2"], x, "layernorm")
        if mode == "fal":
            mlp_in = mlp_in + a1n
        m_partial = _mlp_local(p, mlp_in)
        # the paper's fusion: both partial sums combined in ONE all-reduce
        am = jax.lax.psum(a_partial + m_partial, axis)          # all-reduce 1
        return x + am, am  # a1n candidate needs the assembled a; see block0

    raise ValueError(mode)


def tp_block0_apply(p, x, *, n_heads, tp_size, axis="model"):
    """Block 1 under FAL: must assemble its MHA output (one extra all-reduce,
    paid ONCE for the whole depth) to produce the LN'd first-attention
    signal."""
    h = L.norm_apply(p["ln1"], x, "layernorm")
    a = jax.lax.psum(_attn_local(p, h, n_heads // tp_size), axis)
    a1n = L.norm_apply(p["ln_a"], a, "layernorm")
    mlp_in = L.norm_apply(p["ln2"], x, "layernorm") + a1n
    m = jax.lax.psum(_mlp_local(p, mlp_in), axis)
    return x + a + m, a1n


def make_tp_forward(mesh, n_layers, d, d_ff, n_heads, mode, axis="model"):
    """Builds (init_fn, jitted forward) for an n_layer TP stack on ``mesh``."""
    tp_size = mesh.shape[axis]

    def init_fn(key):
        ks = jax.random.split(key, n_layers)
        return jax.vmap(lambda k: tp_block_init(k, d, d_ff, n_heads))(ks)

    wspec = {
        "ln1": {"scale": P(), "bias": P()},
        "ln2": {"scale": P(), "bias": P()},
        "ln_a": {"scale": P(), "bias": P()},
        "wqkv": P(None, None, None, axis),  # column (stacked on dim 0)
        "wo": P(None, axis, None),     # row
        "wi": P(None, None, axis),
        "wo2": P(None, axis, None),
    }

    def fwd(params, x):
        def local(params, x):
            a1n = jnp.zeros_like(x)
            p0 = jax.tree.map(lambda a: a[0], params)
            if mode == "fal":
                x, a1n = tp_block0_apply(p0, x, n_heads=n_heads,
                                         tp_size=tp_size, axis=axis)
            else:
                x, _ = tp_block_apply(p0, x, a1n, mode=mode, n_heads=n_heads,
                                      tp_size=tp_size, axis=axis)

            def body(h, pb):
                h, _ = tp_block_apply(pb, h, a1n, mode=mode, n_heads=n_heads,
                                      tp_size=tp_size, axis=axis)
                return h, None

            rest = jax.tree.map(lambda a: a[1:], params)
            x, _ = jax.lax.scan(body, x, rest)
            return x

        fn = shard_map(local, mesh=mesh,
                           in_specs=(wspec, P()), out_specs=P(),
                           check_vma=False)
        return fn(params, x)

    return init_fn, jax.jit(fwd)


# ------------------------------------------------------------------------- #
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\b")


def count_collectives(hlo_text: str):
    """Count collective ops in HLO text (instruction definitions only)."""
    counts = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        # match op definitions: "%x = bf16[...] all-reduce(..." etc.
        m = re.search(r"=\s+\S+\s+(all-reduce|all-gather|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start)?\(", line)
        if m:
            counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return counts


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of collective ops in HLO text (roofline ICI
    term).  Parses shapes like 'bf16[2,16,128]{...}'."""
    dt_bytes = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
    total = {}
    pat = re.compile(r"=\s+\(?([a-z0-9]+)\[([0-9,]*)\][^)]*?\s+"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        if dt not in dt_bytes:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total[op] = total.get(op, 0) + n * dt_bytes[dt]
    return total
