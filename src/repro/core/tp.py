"""Tensor-parallel tooling for the unified decoder family.

The explicit partial-sum TP execution itself lives with the model now:
``models/blocks.py::block_apply`` composes head-/hidden-/expert-sharded
local kernels per ``core/fal.py::attention_must_assemble`` and
``models/model.py::decoder_stack_tp`` drives the whole block stack under one
shard_map, selected by an explicit-TP ``core.plan.ExecutionPlan`` (the toy
duplicate-weight stack that used to live here is gone).  Per transformer
block and connection mode the collective structure is the paper's Fig 2:

  preln / falplus : all-reduce(MHA partial) -> MLP -> all-reduce(MLP) = 2
  fal / parallel  : MHA partial + MLP partial added LOCALLY -> ONE all-reduce
  block 0 (fal)   : one extra assemble to export the first-attention signal
                    -> (L+1)/(2L) all-reduce bytes vs preln over L layers

With ``ExecutionPlan(sequence_parallel=True)`` the same structure lowers in
the Megatron-SP layout: every all-reduce above becomes a reduce-scatter at
1/tp the bytes behind an all-gather of the LN region (block 0's signal
export stays the one true all-reduce).

This module keeps what is reusable across tests and benchmarks:

  * ``make_tp_forward`` — thin wrapper that builds a real-``DecoderLM``
    block stack (``models/blocks.py`` weights, GQA attention, cfg.mlp FFN)
    and returns (init_fn, jitted forward) running ``decoder_stack_tp`` on a
    given mesh — the structural harness for asserting the halving (and the
    SP bytes reduction, ``sp=True``) on lowered HLO without hardware.
  * ``count_collectives`` / ``collective_bytes`` — HLO-text parsers for
    collective op counts and payload bytes (scan bodies counted once; use
    ``benchmarks.hlo_cost.analyze`` for trip-count-aware totals).
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp


def bench_stack_config(n_layers, d, d_ff, n_heads, mode):
    """A minimal real-model dense config for TP structure tests/benches."""
    from repro.configs.base import ModelConfig
    return ModelConfig(
        arch_id="tp-bench", family="dense", n_layers=n_layers, d_model=d,
        n_heads=n_heads, n_kv_heads=n_heads, d_ff=d_ff, vocab=256,
        connection=mode, norm="layernorm", mlp="gelu", dtype="float32",
        param_dtype="float32", remat=False, attn_block_q=64)


def make_tp_forward(mesh, n_layers, d, d_ff, n_heads, mode, axis="model",
                    sp=False, grad_compress="none"):
    """(init_fn, jitted forward) for an n_layer unified-block TP stack.

    The params are real ``models/blocks.py`` block weights (the same trees
    ``DecoderLM`` trains); the forward is ``models/model.py::
    decoder_stack_tp`` on ``mesh`` under an explicit-TP ``ExecutionPlan``
    — so HLO lowered from here IS the production collective structure, not
    a toy's.  ``sp=True`` lowers the sequence-parallel layout (activations
    sharded over ``axis`` along the sequence; reduce-scatter/all-gather
    pairs instead of all-reduces).  ``grad_compress`` ∈ {none, int8,
    lowrank} routes the BACKWARD cotangent reductions through
    ``optim/grad_compress.py``'s compressed collectives (forward HLO is
    unchanged; ``bench_comm`` diffs the gradient wire bytes).
    """
    from repro.core.plan import ExecutionPlan
    from repro.models import blocks as BL
    from repro.models import model as M

    cfg = bench_stack_config(n_layers, d, d_ff, n_heads, mode)
    plan = ExecutionPlan.from_mesh(mesh, tp="explicit", sp=sp,
                                   model_axis=axis,
                                   grad_compress=grad_compress).validate(cfg)

    def init_fn(key):
        k0, ks = jax.random.split(key)
        p = {"block0": BL.block_init(k0, cfg, is_block0=True)}
        if n_layers > 1:
            p["blocks_dense"] = jax.vmap(
                lambda k: BL.block_init(k, cfg))(
                jax.random.split(ks, n_layers - 1))
        return p

    def fwd(params, x):
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        y, _ = M.decoder_stack_tp(params, cfg, x, positions, plan)
        return y

    return init_fn, jax.jit(fwd)


def make_tp_decode_step(mesh, d, d_ff, n_heads, mode, axis="model", *,
                        dual=False, page_size=8, num_pages=9):
    """(init_fn, jitted paged decode tick) for ONE steady-state block under
    an explicit-TP shard_map — the structural harness for the dual-branch
    collectives gate: lowering the same tick with ``dual=False`` and
    ``dual=True`` and diffing ``count_collectives`` asserts that MHA||MLP
    branch parallelism adds NO collectives (both pay the single fused
    all-reduce of the fal/parallel steady state; kept by
    ``models/blocks.py::_block_apply_dual`` merging the MHA and MLP partial
    sums before the one psum).

    ``init_fn(key)`` returns (block_params, paged kv cache); the step is
    ``step(params, x (B,1,d), cache, block_tables (B,T), pos (B,),
    n_valid (B,), a1_sig (B,1,d)) -> (x_out, new_cache)``.
    """
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import shard_map
    from repro.core.plan import ExecutionPlan, Phase
    from repro.launch import mesh as MX
    from repro.models import attention as A
    from repro.models import blocks as BL

    cfg = bench_stack_config(1, d, d_ff, n_heads, mode)
    plan = ExecutionPlan.from_mesh(mesh, tp="explicit", phase=Phase.PAGED,
                                   model_axis=axis,
                                   dual_branch=dual).validate(cfg)
    inner = plan.inner()

    def init_fn(key):
        params = BL.block_init(key, cfg, kind="dense")
        cache = A.gqa_init_paged_cache(cfg, num_pages, page_size,
                                       cfg.dtype)
        return params, cache

    kv = P(None, None, axis, None)               # pages: Hkv over model

    def step(params, x, cache, bt, pos, n_valid, a1_sig):
        wspecs = MX.param_specs(params, cfg)

        def local(bp, x, ck, cv, bt, pos, n_valid, sig):
            out, _, _, new_cache = BL.block_apply(
                bp, cfg, x, sig, None, 0, kind="dense", is_block0=False,
                plan=inner, cache={"k": ck, "v": cv}, pos=pos,
                block_tables=bt, n_valid=n_valid)
            return out, new_cache["k"], new_cache["v"]

        fn = shard_map(local, mesh=mesh,
                       in_specs=(wspecs, P(), kv, kv, P(), P(), P(), P()),
                       out_specs=(P(), kv, kv),
                       check_vma=False)
        out, ck, cv = fn(params, x, cache["k"], cache["v"], bt, pos,
                         n_valid, a1_sig)
        return out, {"k": ck, "v": cv}

    return init_fn, jax.jit(step)


def assert_dual_no_extra_collectives(mesh, modes=("fal", "parallel"), *,
                                     check_numeric=True):
    """THE dual-branch structural gate, shared by
    ``benchmarks/bench_serving.py --dual`` and ``tests/test_dual_branch.py``
    (one implementation so the two cannot drift): per mode, lower one
    steady-state block's paged decode tick via ``make_tp_decode_step`` with
    and without ``dual`` and assert the collective counts are IDENTICAL —
    both pay exactly ONE fused all-reduce — and (``check_numeric``) that the
    outputs match.  Returns {mode: {"sequential": counts, "dual": counts}}.
    Needs >= 2 devices in ``mesh``.
    """
    import numpy as np
    B, T, page, d = 2, 4, 8, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, d))
    bt = jnp.asarray(np.arange(1, 1 + B * T).reshape(B, T), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    nv = jnp.ones((B,), jnp.int32)
    sig = jax.random.normal(jax.random.PRNGKey(2), (B, 1, d))
    result = {}
    for mode in modes:
        counts, outs = {}, {}
        for dual in (False, True):
            init_fn, step = make_tp_decode_step(mesh, d, 128, 4, mode,
                                                dual=dual, page_size=page)
            params, cache = init_fn(jax.random.PRNGKey(0))
            with mesh:
                hlo = step.lower(params, x, cache, bt, pos, nv,
                                 sig).compile().as_text()
                outs[dual], _ = step(params, x, cache, bt, pos, nv, sig)
            counts["dual" if dual else "sequential"] = \
                count_collectives(hlo)
        assert counts["sequential"].get("all-reduce", 0) == 1, (mode, counts)
        assert counts["dual"] == counts["sequential"], (mode, counts)
        if check_numeric:
            err = float(jnp.max(jnp.abs(outs[True] - outs[False])))
            assert err < 1e-5, (mode, err)
        result[mode] = counts
    return result


# ------------------------------------------------------------------------- #
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\b")


def count_collectives(hlo_text: str):
    """Count collective ops in HLO text (instruction definitions only)."""
    counts = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        # match op definitions: "%x = bf16[...] all-reduce(..." etc.
        m = re.search(r"=\s+\S+\s+(all-reduce|all-gather|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start)?\(", line)
        if m:
            counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return counts


_DT_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
             "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
             "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of collective ops in HLO text (roofline ICI
    term).  Parses shapes like 'bf16[2,16,128]{...}'."""
    total = {}
    pat = re.compile(r"=\s+\(?([a-z0-9]+)\[([0-9,]*)\][^)]*?\s+"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total[op] = total.get(op, 0) + n * _DT_BYTES[dt]
    return total


_COLL_DEF_RE = re.compile(
    r"\s(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_payload_bytes(hlo_text: str, tp: int):
    """Per-device WIRE bytes of every collective in HLO text under a ring
    model — the quantity gradient compression actually shrinks (the naive
    output-shape sum misranks e.g. an int8 all_gather whose OUTPUT is full
    size but whose wire traffic is 1/tp of it):

      all-reduce      2·out·(tp-1)/tp   (reduce-scatter + all-gather ring)
      all-gather        out·(tp-1)/tp   (out = the gathered full tensor)
      reduce-scatter    out·(tp-1)      (out = the reduced shard)
      all-to-all        out·(tp-1)/tp   (keeps 1/tp of its own data local)
      collective-permute out

    Unlike ``collective_bytes`` this handles TUPLE-output collectives (XLA
    lowers ``lax.all_to_all`` to one, which the single-shape regex drops)
    by summing every shape token in the output type.  ``-done`` halves of
    async pairs are skipped; ``-start`` counts once.  Returns
    {op: per-device wire bytes}."""
    total = {}
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        m = _COLL_DEF_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        out = 0
        for dt, dims in _SHAPE_RE.findall(line[line.index(" = "):m.start()]):
            if dt not in _DT_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            out += n * _DT_BYTES[dt]
        if op == "all-reduce":
            wire = 2 * out * (tp - 1) // tp
        elif op in ("all-gather", "all-to-all"):
            wire = out * (tp - 1) // tp
        elif op == "reduce-scatter":
            wire = out * (tp - 1)
        else:
            wire = out
        total[op] = total.get(op, 0) + wire
    return total
