"""Version shims for the host framework.

``shard_map`` moved from ``jax.experimental.shard_map`` (kwarg
``check_rep``) to top-level ``jax.shard_map`` (kwarg ``check_vma``); this
repo runs on both sides of the move.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
