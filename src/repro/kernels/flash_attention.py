"""Pallas TPU flash attention (causal, GQA) — online-softmax with explicit
BlockSpec VMEM tiling.

TPU adaptation of the paper's FlashAttention dependency (§6.3): the grid's
minor-most dimension iterates KV blocks sequentially (TPU grids execute in
order), with the running max/denominator/accumulator in VMEM scratch.  Block
shapes are MXU-aligned (multiples of 128 on the lane dimension).

Target: TPU.  Validated with ``interpret=True`` on CPU against
``repro.kernels.ref.attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, block_q, block_k, seq_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_k
        if causal:
            mask &= k_pos <= q_pos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                              # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        # skip fully-masked KV blocks (block start beyond the last q row)
        pl.when(k_start <= q_start + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ik == nk - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    scale=None, interpret=False):
    """q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, H, D).

    GQA handled by mapping query head h to kv head h // (H // Hkv) in the
    K/V BlockSpec index maps (no materialised broadcast).
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)

    q_pad = (-Sq) % block_q
    k_pad = (-Sk) % block_k
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k

    # layout (B, H, S, D) so the block tiles the last two dims
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, nq, nk)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k, seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :Sq]
