"""Pallas TPU fused dual-branch decode kernel: paged MHA gather || dense FFN.

Under the FAL-family connections (``core.fal.DUAL_BRANCH_MODES``) a decode
block's MLP input is independent of the block's own attention output, so the
attention branch (DMA-bound block-table page gather) and the FFN branch
(MXU-bound matmuls) can execute concurrently.  A single XLA program cannot
promise that overlap — this kernel enforces it: ONE ``pallas_call`` whose
grid interleaves the paged-attention page steps with FFN hidden-dim tiles,
so the DMA of page t+1 prefetches while the MXU runs FFN tile t's matmuls.

Grid: (B, Hkv, T), sequential on TPU.  The attention half is exactly the
``paged_attention`` online-softmax kernel (block table + seq_lens ride in as
scalar prefetch; each step DMAs one physical page).  The FFN half splits the
hidden dim F into ``Hkv * T`` column tiles of wi/wg (and matching row tiles
of wo); step (h, t) accumulates tile ``h*T + t``'s contribution to the FFN
output row in fp32 VMEM scratch.  Emission: attention out at the last page
step of each (b, h); FFN out at the last (h, t) step of each b.

Requires F % (Hkv * T) == 0 (the ``kernels.ops.dual_branch_decode``
dispatcher falls back to separate attention + FFN calls otherwise — still
dependency-free, just not fused).  Tile width F/(Hkv*T) is ideally a
multiple of 128 (lane width); smaller tiles are compiler-padded.

Target: TPU.  Validated with ``interpret=True`` on CPU against
``ref.paged_attention_ref`` + ``layers.mlp_apply`` in ``tests/test_dual_branch.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dual_kernel(bt_ref, sl_ref, q_ref, k_ref, v_ref, x_ref, *ffn_refs,
                 scale, page_size, kind):
    if kind in ("swiglu", "geglu"):
        wi_ref, wg_ref, wo_ref, o_ref, f_ref, m_scr, l_scr, acc_scr, \
            ffn_scr = ffn_refs
    else:
        wi_ref, wo_ref, o_ref, f_ref, m_scr, l_scr, acc_scr, \
            ffn_scr = ffn_refs
        wg_ref = None
    b = pl.program_id(0)
    ih = pl.program_id(1)
    it = pl.program_id(2)
    nh = pl.num_programs(1)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init_attn():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when((ih == 0) & (it == 0))
    def _init_ffn():
        ffn_scr[...] = jnp.zeros_like(ffn_scr)

    # ---- FFN branch: one hidden-dim tile per grid step (MXU) -------------
    xr = x_ref[...].astype(jnp.float32)                   # (1, Dm)
    hi = jax.lax.dot_general(
        xr, wi_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (1, Ft)
    if kind == "swiglu":
        hg = jax.lax.dot_general(
            xr, wg_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        hpart = jax.nn.silu(hg) * hi
    elif kind == "geglu":
        hg = jax.lax.dot_general(
            xr, wg_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        hpart = jax.nn.gelu(hg) * hi
    else:  # gelu
        hpart = jax.nn.gelu(hi)
    ffn_scr[...] += jax.lax.dot_general(
        hpart, wo_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (1, Dm)

    # ---- attention branch: one physical page per grid step (DMA + VPU) ---
    seq_len = sl_ref[b]
    k_start = it * page_size

    def _attn_body():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (page, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, page)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < seq_len, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32)               # (page, Dv)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    pl.when(k_start < seq_len)(_attn_body)

    @pl.when(it == nt - 1)
    def _emit_attn():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)

    @pl.when((ih == nh - 1) & (it == nt - 1))
    def _emit_ffn():
        f_ref[...] = ffn_scr[...].astype(f_ref.dtype)


def fused_dual_branch_decode(q, k_pages, v_pages, block_tables, seq_lens,
                             x, ffn, *, kind="swiglu", scale=None,
                             interpret=False):
    """q: (B, H, D); k_pages/v_pages: (P, page, Hkv, D*); block_tables:
    (B, T) int32; seq_lens: (B,) int32; x: (B, Dm) FFN input rows; ffn:
    {"wi" (Dm, F) [, "wg" (Dm, F)], "wo" (F, Dm)}.
    Returns (attn (B, H, Dv), ffn_out (B, Dm))."""
    B, H, D = q.shape
    page, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    G = H // Hkv
    T = block_tables.shape[1]
    Dm = x.shape[-1]
    F = ffn["wi"].shape[-1]
    n_tiles = Hkv * T
    if F % n_tiles:
        raise ValueError(f"fused dual-branch: d_ff={F} must divide into "
                         f"Hkv*T={n_tiles} tiles (dispatcher should have "
                         f"fallen back)")
    Ft = F // n_tiles
    scale = D ** -0.5 if scale is None else scale
    gated = kind in ("swiglu", "geglu")

    qg = q.reshape(B, Hkv, G, D)
    kt = k_pages.transpose(0, 2, 1, 3)                # (P, Hkv, page, D)
    vt = v_pages.transpose(0, 2, 1, 3)

    # FFN tile index for grid step (b, h, t): j = h*T + t
    in_specs = [
        pl.BlockSpec((1, 1, G, D), lambda b, h, t, bt, sl: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, page, D),
                     lambda b, h, t, bt, sl: (bt[b, t], h, 0, 0)),
        pl.BlockSpec((1, 1, page, Dv),
                     lambda b, h, t, bt, sl: (bt[b, t], h, 0, 0)),
        pl.BlockSpec((1, Dm), lambda b, h, t, bt, sl: (b, 0)),
        pl.BlockSpec((Dm, Ft), lambda b, h, t, bt, sl: (0, h * T + t)),
    ]
    operands = [qg, kt, vt, x, ffn["wi"]]
    if gated:
        in_specs.append(
            pl.BlockSpec((Dm, Ft), lambda b, h, t, bt, sl: (0, h * T + t)))
        operands.append(ffn["wg"])
    in_specs.append(
        pl.BlockSpec((Ft, Dm), lambda b, h, t, bt, sl: (h * T + t, 0)))
    operands.append(ffn["wo"])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, T),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, G, Dv), lambda b, h, t, bt, sl: (b, h, 0, 0)),
            pl.BlockSpec((1, Dm), lambda b, h, t, bt, sl: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
            pltpu.VMEM((1, Dm), jnp.float32),
        ],
    )
    kernel = functools.partial(_dual_kernel, scale=scale, page_size=page,
                               kind=kind)
    out, ffn_out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
                   jax.ShapeDtypeStruct((B, Dm), x.dtype)],
        interpret=interpret,
    )(block_tables.astype(jnp.int32), seq_lens.astype(jnp.int32), *operands)
    return out.reshape(B, H, Dv), ffn_out
