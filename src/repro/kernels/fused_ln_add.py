"""Pallas TPU kernel for FAL's MLP-input fusion:  y = LN(x) + a1n.

This is the hot elementwise path FAL adds to every block (eq 2).  Fusing the
LayerNorm with the first-attention add performs one HBM read of x, one of
a1n, and one write of y — instead of materialising LN(x) to HBM first.
Row-tiled: grid over row blocks, the full feature dimension stays in VMEM
(d_model <= 8192 => <= 64 KB per row, fine).

Target: TPU.  Validated with ``interpret=True`` against
``repro.kernels.ref.ln_add_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_add_kernel(x_ref, a1_ref, scale_ref, bias_ref, o_ref, *, eps,
                   kind):
    x = x_ref[...].astype(jnp.float32)                    # (rows, d)
    if kind == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps)
        y = y * scale_ref[...].astype(jnp.float32) \
            + bias_ref[...].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + eps)
        y = y * scale_ref[...].astype(jnp.float32)
    o_ref[...] = (y + a1_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def fused_ln_add(x, a1n, scale, bias=None, *, kind="rmsnorm", eps=1e-6,
                 block_rows=256, interpret=False):
    """x, a1n: (..., d) -> LN(x) + a1n, one pass."""
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    a2 = a1n.reshape(-1, d)
    rows = x2.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        a2 = jnp.pad(a2, ((0, pad), (0, 0)))
    n = x2.shape[0] // block_rows
    if bias is None:
        bias = jnp.zeros((d,), scale.dtype)

    kernel = functools.partial(_ln_add_kernel, eps=eps, kind=kind)
    out = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, a2, scale, bias)
    return out[:rows].reshape(orig_shape)
