"""Pure-jnp oracles for the Pallas kernels (used by tests + interpret-mode
validation sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, scale=None):
    """Naive attention oracle.  q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D*)."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def ln_add_ref(x, a1n, scale, bias=None, *, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return (y + a1n.astype(jnp.float32)).astype(x.dtype)
