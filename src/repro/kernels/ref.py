"""Pure-jnp oracles for the Pallas kernels (used by tests + interpret-mode
validation sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, scale=None):
    """Naive attention oracle.  q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D*)."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens, *,
                        scale=None):
    """Paged-KV decode attention oracle (gather-based).

    q: (B, H, D) one query token per request;
    k_pages/v_pages: (P, page_size, Hkv, D*) pools;
    block_tables: (B, T) int32 logical-block -> physical-page;
    seq_lens: (B,) valid keys per request (gathered index < seq_len).
    Returns (B, H, Dv).
    """
    B, H, D = q.shape
    Hkv = k_pages.shape[2]
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    k = k_pages[block_tables]                     # (B, T, page, Hkv, D)
    k = k.reshape(B, -1, Hkv, D)
    v = v_pages[block_tables].reshape(B, -1, Hkv, v_pages.shape[-1])
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(k.shape[1])[None] < seq_lens[:, None]
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, v.shape[-1]).astype(q.dtype)


def ln_add_ref(x, a1n, scale, bias=None, *, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return (y + a1n.astype(jnp.float32)).astype(x.dtype)
