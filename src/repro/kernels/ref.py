"""Pure-jnp oracles for the Pallas kernels (used by tests + interpret-mode
validation sweeps).

The paged oracles take optional per-page-row ``k_scale``/``v_scale`` pools
((P, page_size) fp32, shared across KV heads — the quantized-KV page
format): when present, gathered K/V rows are dequantized as
``row.astype(f32) * scale`` right where the unquantized path upcasts, so
the fp32 softmax math downstream is IDENTICAL and the only difference is
the storage rounding."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _dequant(rows, scale_pages, block_tables):
    """rows: gathered K/V (..., Sk, Hkv, D) already fp32; scale_pages:
    (P, page_size) fp32 per-row scales or None; block_tables matches the
    gather that produced ``rows``.  Returns rows * scale (broadcast over
    heads and head dim)."""
    if scale_pages is None:
        return rows
    s = scale_pages[block_tables]                 # (..., T, page)
    s = s.reshape(s.shape[:-2] + (-1,))           # (..., Sk)
    return rows * s[..., None, None].astype(jnp.float32)


def attention_ref(q, k, v, *, causal=True, scale=None):
    """Naive attention oracle.  q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D*)."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens, *,
                        scale=None, k_scale=None, v_scale=None):
    """Paged-KV decode attention oracle (gather-based).

    q: (B, H, D) one query token per request;
    k_pages/v_pages: (P, page_size, Hkv, D*) pools;
    block_tables: (B, T) int32 logical-block -> physical-page;
    seq_lens: (B,) valid keys per request (gathered index < seq_len);
    k_scale/v_scale: optional (P, page_size) fp32 dequant scale pools.
    Returns (B, H, Dv).
    """
    B, H, D = q.shape
    Hkv = k_pages.shape[2]
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    k = k_pages[block_tables]                     # (B, T, page, Hkv, D)
    k = _dequant(k.reshape(B, -1, Hkv, D).astype(jnp.float32),
                 k_scale, block_tables)
    v = _dequant(v_pages[block_tables].reshape(
        B, -1, Hkv, v_pages.shape[-1]).astype(jnp.float32),
        v_scale, block_tables)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(k.shape[1])[None] < seq_lens[:, None]
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, v.shape[-1]).astype(q.dtype)


def paged_chunk_attention_ref(q, k_pages, v_pages, block_tables, pos,
                              n_valid, *, scale=None, k_scale=None,
                              v_scale=None):
    """Chunked paged-attention oracle (gather-based): C >= 1 query tokens per
    lane against block-table pages, causal within the chunk.

    q: (B, C, H, D) — lane b's queries sit at logical positions
    ``pos[b] .. pos[b] + C - 1``, of which the first ``n_valid[b]`` are
    valid (the chunk's own K/V have already been scattered into the pools);
    k_pages/v_pages: (P, page_size, Hkv, D*);  block_tables: (B, T) int32;
    pos/n_valid: (B,) int32.  Returns (B, C, H, Dv).

    A key at gathered index j is visible to chunk lane c iff
    ``j <= pos + c`` (causality, incl. within the chunk) and
    ``j < pos + n_valid`` (this lane's live history).  Rows past
    ``n_valid`` are finite but MEANINGLESS — they attend the lane's live
    history under the same mask, and rows with no visible key return 0 —
    the identical convention to the Pallas kernel, so the two agree on
    every row; callers must only read the first ``n_valid`` rows.
    """
    B, C, H, D = q.shape
    Hkv = k_pages.shape[2]
    G = H // Hkv
    Dv = v_pages.shape[-1]
    scale = D ** -0.5 if scale is None else scale
    k = _dequant(k_pages[block_tables].reshape(
        B, -1, Hkv, D).astype(jnp.float32), k_scale, block_tables)
    v = _dequant(v_pages[block_tables].reshape(
        B, -1, Hkv, Dv).astype(jnp.float32), v_scale, block_tables)
    qg = q.reshape(B, C, Hkv, G, D)
    s = jnp.einsum("bchgd,bkhd->bhgck", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    k_pos = jnp.arange(k.shape[1])[None, None]                  # (1, 1, Sk)
    q_pos = pos[:, None] + jnp.arange(C)[None]                  # (B, C)
    seq_len = (pos + n_valid)[:, None, None]
    mask = (k_pos <= q_pos[:, :, None]) & (k_pos < seq_len)     # (B, C, Sk)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[:, None, None, :, None], p, 0.0)
    o = jnp.einsum("bhgck,bkhd->bchgd", p, v.astype(jnp.float32))
    return o.reshape(B, C, H, Dv).astype(q.dtype)


def paged_packed_attention_ref(q, k_pages, v_pages, block_tables, tok_slot,
                               tok_pos, *, scale=None, k_scale=None,
                               v_scale=None):
    """Packed ragged paged-attention oracle (gather-based): a flat (T,)
    token buffer where token t belongs to lane ``tok_slot[t]`` at logical
    position ``tok_pos[t]`` — the segment-aware generalisation of
    ``paged_chunk_attention_ref`` that backs the token-packed tick.

    q: (T, H, D) packed query tokens; k_pages/v_pages: (P, page_size,
    Hkv, D*); block_tables: (S, Tb) int32 per-SLOT tables; tok_slot /
    tok_pos: (T,) int32.  Returns (T, H, Dv).

    Token t sees exactly the keys of its own slot's block table at
    gathered index j <= tok_pos[t] (causality; its own K/V and every
    earlier token of its segment are already scattered into the pools).
    Padding tokens carry tok_pos == -1: no key is visible and the row
    returns 0 — the identical convention to the Pallas kernel, so the two
    agree on every row; callers must only read live (tok_pos >= 0) rows.

    This is also the speculative-decode VERIFY oracle: a decode lane
    proposing n tokens packs them as one segment at positions
    pos..pos+n-1, and the per-token causal mask scores proposal j against
    exactly the context [0, pos+j] — so every row's attention equals what
    a sequential one-token-per-tick decode would have computed at that
    position.  K/V scattered for later-REJECTED proposals sit at
    positions beyond the lane's rewound ``pos``; ``k_pos <= tok_pos``
    keeps them invisible until the position is re-fed, at which point the
    scatter overwrites them before any read.
    """
    T, H, D = q.shape
    Hkv = k_pages.shape[2]
    G = H // Hkv
    Dv = v_pages.shape[-1]
    scale = D ** -0.5 if scale is None else scale
    bt = block_tables[tok_slot]                    # (T, Tb) per-token tables
    k = _dequant(k_pages[bt].reshape(
        T, -1, Hkv, D).astype(jnp.float32), k_scale, bt)   # (T, Sk, Hkv, D)
    v = _dequant(v_pages[bt].reshape(
        T, -1, Hkv, Dv).astype(jnp.float32), v_scale, bt)
    qg = q.reshape(T, Hkv, G, D)
    s = jnp.einsum("thgd,tkhd->thgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    k_pos = jnp.arange(k.shape[1])[None]                        # (1, Sk)
    mask = k_pos <= tok_pos[:, None]                            # (T, Sk)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[:, None, None, None], p, 0.0)
    o = jnp.einsum("thgk,tkhd->thgd", p, v.astype(jnp.float32))
    return o.reshape(T, H, Dv).astype(q.dtype)


def copy_pages_ref(pool, src, dst):
    """Oracle for the COW page copy: pool (P, page, ...) with the full rows
    at pages ``src`` (n,) written over the rows at pages ``dst`` (n,).
    ``dst`` indices must be distinct (each COW target is a freshly
    allocated page); ``src`` pages are read-only and may repeat."""
    return pool.at[dst].set(pool[src])


def ln_add_ref(x, a1n, scale, bias=None, *, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return (y + a1n.astype(jnp.float32)).astype(x.dtype)
