"""jit'd public wrappers for the Pallas kernels.

On a CPU host (this container, and the dry-run) Pallas TPU kernels cannot
lower, so ``use_pallas=False`` (default on CPU) dispatches to the jnp
blockwise/fused implementations with identical numerics.  On TPU, pass
``use_pallas=True`` (or set REPRO_USE_PALLAS=1) to run the kernels.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_ln_add as _fla
from repro.kernels import ref as _ref


def _default_use_pallas():
    if os.environ.get("REPRO_USE_PALLAS"):
        return True
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    use_pallas=None, interpret=False):
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                                   block_k=block_k, interpret=interpret)
    from repro.models.attention import blockwise_attention
    return blockwise_attention(q, k, v, causal=causal, block_q=block_q)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                           use_pallas=None, interpret=False):
    """Paged-KV decode attention: q (B,H,D) against (P,page,Hkv,D*) pools
    addressed through (B,T) block tables.  Pallas kernel on TPU; gather-based
    jnp oracle on CPU (identical numerics)."""
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        from repro.kernels import paged_attention as _pa
        return _pa.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                          seq_lens, interpret=interpret)
    return _ref.paged_attention_ref(q, k_pages, v_pages, block_tables,
                                    seq_lens)


@functools.partial(jax.jit, static_argnames=("kind", "use_pallas",
                                             "interpret"))
def fused_ln_add(x, a1n, scale, bias=None, *, kind="rmsnorm",
                 use_pallas=None, interpret=False):
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _fla.fused_ln_add(x, a1n, scale, bias, kind=kind,
                                 interpret=interpret)
    return _ref.ln_add_ref(x, a1n, scale, bias, kind=kind)
