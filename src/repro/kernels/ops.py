"""jit'd public wrappers for the Pallas kernels.

On a CPU host (this container, and the dry-run) Pallas TPU kernels cannot
lower, so ``use_pallas=False`` (default on CPU) dispatches to the jnp
blockwise/fused implementations with identical numerics.  On TPU, pass
``use_pallas=True`` (or set REPRO_USE_PALLAS=1) to run the kernels.

Every dispatcher records the path it lowered (``fused-tpu`` vs
``cpu-fallback``) per call site into the default metrics registry
(``repro.obs``) at trace time — a traced program's path cannot change
without a re-trace, so ``dispatch_paths()`` is the ground truth the
benchmark JSONs stamp as ``dispatch_path`` (a runtime measurement, not a
bench-side guess).  ``kernel_dispatch_total`` therefore counts TRACES, not
executed calls.
"""
from __future__ import annotations

import contextlib
import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_ln_add as _fla
from repro.kernels import ref as _ref
from repro.obs import metrics as _metrics

FUSED = "fused-tpu"
FALLBACK = "cpu-fallback"

#: last path traced per dispatcher call site (survives registry resets:
#: a warmup reset must not un-measure an already-compiled program)
_DISPATCH_PATHS = {}


#: dotted suffix applied to the next recorded sites (``dispatch_site_suffix``)
_SITE_SUFFIX = ""


def _record_dispatch(site: str, fused: bool) -> str:
    if _SITE_SUFFIX:
        site = f"{site}.{_SITE_SUFFIX}"
    path = FUSED if fused else FALLBACK
    _DISPATCH_PATHS[site] = path
    _metrics.default_registry().counter(
        f"kernel_dispatch_total.{site}.{path}", unit="traces",
        site="kernels/ops.py").inc()
    return path


@contextlib.contextmanager
def dispatch_site_suffix(suffix: str):
    """Label dispatches traced inside the context with ``<site>.<suffix>``.

    Dispatch recording happens at TRACE time, so a caller that traces a
    sub-program under this context (e.g. the speculative-decode DRAFT
    early-exit forward inside the engine's one jitted tick) gets its kernel
    paths telemetered separately from the verify path's — same dispatcher,
    distinct ``dispatch_paths()`` rows (``paged_packed_attention`` vs
    ``paged_packed_attention.draft``)."""
    global _SITE_SUFFIX
    prev, _SITE_SUFFIX = _SITE_SUFFIX, suffix
    try:
        yield
    finally:
        _SITE_SUFFIX = prev


def _kv_variant(site: str, k_pages, k_scale) -> str:
    """Dotted site label for quantized-KV dispatches: a paged call with
    scale pools present traces as ``<site>.<storage>`` (e.g.
    ``paged_packed_attention.int8``) so runtime telemetry separates the
    quantized engine's kernel path from the unquantized one.  Scale-less
    calls keep the bare site name whatever the cache dtype."""
    if k_scale is None:
        return site
    name = jnp.dtype(k_pages.dtype).name
    if name.startswith("float8"):
        name = "fp8"
    return f"{site}.{name}"


def dispatch_paths() -> dict:
    """{call site: 'fused-tpu' | 'cpu-fallback'} for every dispatcher
    traced so far in this process."""
    return dict(_DISPATCH_PATHS)


def reset_dispatch_paths():
    """Testing hook: forget recorded paths (jit caches survive, so only
    sites re-traced afterwards will reappear)."""
    _DISPATCH_PATHS.clear()


def _default_use_pallas():
    if os.environ.get("REPRO_USE_PALLAS"):
        return True
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    use_pallas=None, interpret=False):
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    _record_dispatch("flash_attention", use_pallas or interpret)
    if use_pallas or interpret:
        return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                                   block_k=block_k, interpret=interpret)
    from repro.models.attention import blockwise_attention
    return blockwise_attention(q, k, v, causal=causal, block_q=block_q)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                           k_scale=None, v_scale=None, use_pallas=None,
                           interpret=False):
    """Paged-KV decode attention: q (B,H,D) against (P,page,Hkv,D*) pools
    addressed through (B,T) block tables; optional (P,page) fp32
    ``k_scale``/``v_scale`` pools dequantize narrow-dtype pages at the
    VMEM load (fp32 softmax accumulate).  Pallas kernel on TPU;
    gather-based jnp oracle on CPU (identical numerics)."""
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    _record_dispatch(_kv_variant("paged_decode_attention", k_pages, k_scale),
                     use_pallas or interpret)
    if use_pallas or interpret:
        from repro.kernels import paged_attention as _pa
        return _pa.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                          seq_lens, k_scale=k_scale,
                                          v_scale=v_scale,
                                          interpret=interpret)
    return _ref.paged_attention_ref(q, k_pages, v_pages, block_tables,
                                    seq_lens, k_scale=k_scale,
                                    v_scale=v_scale)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_chunk_attention(q, k_pages, v_pages, block_tables, pos, n_valid, *,
                          k_scale=None, v_scale=None, use_pallas=None,
                          interpret=False):
    """Chunked paged attention (per-lane rectangular layout; the serving
    engine now packs tokens through ``paged_packed_attention``): q (B,C,H,D)
    chunks at per-lane positions ``pos`` (first ``n_valid`` rows of each
    lane valid, causal within the chunk) against (P,page,Hkv,D*) pools
    addressed through (B,T) block tables.  One dispatch serves lanes at ANY
    phase — prefilling lanes ride with n_valid up to C, decoding lanes with
    n_valid == 1; rows past a lane's ``n_valid`` are finite but meaningless
    and must not be read.  Pallas kernel on TPU; gather-based jnp oracle on
    CPU (identical numerics)."""
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    _record_dispatch(_kv_variant("paged_chunk_attention", k_pages, k_scale),
                     use_pallas or interpret)
    if use_pallas or interpret:
        from repro.kernels import paged_attention as _pa
        return _pa.paged_chunk_attention(q, k_pages, v_pages, block_tables,
                                         pos, n_valid, k_scale=k_scale,
                                         v_scale=v_scale,
                                         interpret=interpret)
    return _ref.paged_chunk_attention_ref(q, k_pages, v_pages, block_tables,
                                          pos, n_valid, k_scale=k_scale,
                                          v_scale=v_scale)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_packed_attention(q, k_pages, v_pages, block_tables, tok_slot,
                           tok_pos, *, k_scale=None, v_scale=None,
                           use_pallas=None, interpret=False):
    """Packed ragged paged attention (the token-packed serving kernel):
    q (T,H,D) — one flat token buffer where token t belongs to lane
    ``tok_slot[t]`` at logical position ``tok_pos[t]`` — against
    (P,page,Hkv,D*) pools addressed through per-SLOT (S,Tb) block tables.
    One dispatch serves lanes at ANY phase with FLOPs scaling in live
    tokens: a prefilling lane contributes up to ``chunk`` tokens, a
    decoding lane one — or, under self-speculative decoding, its whole
    n-token proposal: the VERIFY pass is this same kernel (a decode lane
    proposing n tokens is just a segment of length n at positions
    pos..pos+n-1; per-segment causality scores every proposal in the one
    dispatch, and K/V at later-rejected positions stay causally masked
    until overwritten when the position is re-fed).  Padding tokens carry
    tok_pos == -1 and emit exactly 0; callers must only read live rows.
    Pallas kernel on TPU; gather-based jnp oracle on CPU (identical
    numerics)."""
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    _record_dispatch(_kv_variant("paged_packed_attention", k_pages, k_scale),
                     use_pallas or interpret)
    if use_pallas or interpret:
        from repro.kernels import paged_attention as _pa
        return _pa.paged_packed_attention(q, k_pages, v_pages, block_tables,
                                          tok_slot, tok_pos, k_scale=k_scale,
                                          v_scale=v_scale,
                                          interpret=interpret)
    return _ref.paged_packed_attention_ref(q, k_pages, v_pages, block_tables,
                                           tok_slot, tok_pos, k_scale=k_scale,
                                           v_scale=v_scale)


@functools.partial(jax.jit, static_argnames=("kind", "use_pallas",
                                             "interpret"))
def dual_branch_decode(q, k_pages, v_pages, block_tables, seq_lens, mlp_in,
                       ffn, *, kind="swiglu", use_pallas=None,
                       interpret=False):
    """Dual-branch decode tick: paged attention gather || dense FFN, issued
    as one dependency-free dispatch (the FAL MHA||MLP property at serving
    time).  q: (B, H, D) one query token per request; mlp_in: (B, 1, Dm)
    the block's MLP input (independent of this block's attention); ffn:
    dense-MLP params {"wi"[, "wg"], "wo"}.  Returns
    (attn (B, H, Dv), ffn_out (B, 1, Dm)).

    On TPU (or interpret mode), when d_ff divides into Hkv*T tiles, both
    branches run in ONE fused Pallas kernel that overlaps the block-table
    page DMAs with the FFN matmuls (``kernels.dual_branch``); otherwise the
    branches are issued as two independent ops (XLA overlaps them).  The
    CPU path runs exactly the ops of the sequential decode path — the
    gather-based ref oracle plus ``layers.mlp_apply`` — so dual-branch
    logits are bit-identical to sequential ones."""
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    from repro.models.layers import mlp_apply
    n_tiles = k_pages.shape[2] * block_tables.shape[1]
    _record_dispatch("dual_branch_decode", use_pallas or interpret)
    if (use_pallas or interpret) and ffn["wi"].shape[-1] % n_tiles == 0:
        from repro.kernels import dual_branch as _db
        attn, y = _db.fused_dual_branch_decode(
            q, k_pages, v_pages, block_tables, seq_lens, mlp_in[:, 0], ffn,
            kind=kind, interpret=interpret)
        return attn, y[:, None]
    if use_pallas:
        from repro.kernels import paged_attention as _pa
        attn = _pa.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                          seq_lens)
    else:
        attn = _ref.paged_attention_ref(q, k_pages, v_pages, block_tables,
                                        seq_lens)
    return attn, mlp_apply(ffn, mlp_in, kind)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def copy_pages(pool, src, dst, *, use_pallas=None, interpret=False):
    """COW page duplication: pool (P, page, ...) with the full rows at
    pages ``src`` (n,) copied over pages ``dst`` (n,) — the device memcpy
    behind ``BlockTable`` copy-on-write (a write into a prefix-shared page
    first lands the history on a private page).  Pallas in-place kernel on
    TPU (pool aliased into the output); scatter-based jnp oracle on CPU
    (identical bytes)."""
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    _record_dispatch("copy_pages", use_pallas or interpret)
    if use_pallas or interpret:
        from repro.kernels import paged_attention as _pa
        return _pa.page_copy(pool, src, dst, interpret=interpret)
    return _ref.copy_pages_ref(pool, src, dst)


@functools.partial(jax.jit, static_argnames=("kind", "use_pallas",
                                             "interpret"))
def fused_ln_add(x, a1n, scale, bias=None, *, kind="rmsnorm",
                 use_pallas=None, interpret=False):
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    _record_dispatch("fused_ln_add", use_pallas or interpret)
    if use_pallas or interpret:
        return _fla.fused_ln_add(x, a1n, scale, bias, kind=kind,
                                 interpret=interpret)
    return _ref.ln_add_ref(x, a1n, scale, bias, kind=kind)
