"""Pallas TPU paged-attention kernels (GQA, block-table gather).

``paged_decode_attention`` — one query token per request attends to its KV
history stored in fixed-size pages scattered through
(num_pages, page_size, Hkv, D) pools.  ``paged_chunk_attention`` — the
C >= 1 generalisation of the padded (slots, C) layout: every lane carries
a C-token query chunk at its own position (per-lane ``pos`` / ``n_valid``
vectors), causal within the chunk.  ``paged_packed_attention`` — the
segment-aware kernel behind the serving engine's token-PACKED tick: one
flat (T,) token buffer with per-token ``(slot, pos)`` ids, so a
prefilling lane contributes up to ``chunk`` tokens and a decoding lane
exactly one in the SAME dispatch, and the tick's FLOPs scale with live
tokens instead of slots x chunk.

In both kernels the block table and per-request positions ride in as
scalar-prefetch operands (``PrefetchScalarGridSpec``): the K/V BlockSpec
index maps read the block table directly, so each grid step DMAs exactly
one physical page into VMEM — no gathered (B, T*page) copy is ever
materialised in HBM.

Quantized KV pages: every paged kernel takes optional per-page-row
``k_scale``/``v_scale`` pools ((P, page_size) fp32, shared across KV
heads).  When present, the page's scale row rides the same block-table
index map as its K/V page (one extra tiny DMA per page step) and the
narrow-dtype page is dequantized at the existing ``.astype(f32)`` load —
narrow in, fp32 softmax accumulate — so the online-softmax math is
byte-identical to the unquantized path and only the storage rounding
differs.  With scales absent the lowered program is unchanged.

Grid: (B, Hkv, T) with T sequential (TPU grids execute in order); the G
query heads sharing a kv head are processed together as a (G, D) tile —
(C*G, D) for the chunked kernel — so the page matmuls hit the MXU.
Online-softmax running max/denominator/accumulator live in VMEM scratch,
carried across the T page steps; pages whose first slot is at/beyond the
lane's live history are skipped with ``pl.when``.

Target: TPU.  Validated with ``interpret=True`` on CPU against
``repro.kernels.ref.paged_attention_ref`` /
``ref.paged_chunk_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, sl_ref, q_ref, k_ref, v_ref, *rest,
                  scale, page_size, quantized=False):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = sl_ref[b]
    k_start = it * page_size          # logical position of this page's slot 0

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (page, D)
        if quantized:
            k = k * ks_ref[0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, page)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < seq_len, s, NEG_INF)

        m_prev = m_scr[...]                               # (G,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32)               # (page, Dv)
        if quantized:
            v = v * vs_ref[0].astype(jnp.float32)[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    # skip pages entirely past the request's history
    pl.when(k_start < seq_len)(_body)

    @pl.when(it == nt - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                           scale=None, k_scale=None, v_scale=None,
                           interpret=False):
    """q: (B, H, D); k_pages/v_pages: (P, page, Hkv, D*);
    block_tables: (B, T) int32; seq_lens: (B,) int32;
    k_scale/v_scale: optional (P, page) fp32 dequant pools -> (B, H, Dv)."""
    B, H, D = q.shape
    page, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    G = H // Hkv
    T = block_tables.shape[1]
    scale = D ** -0.5 if scale is None else scale
    quantized = k_scale is not None

    qg = q.reshape(B, Hkv, G, D)
    kt = k_pages.transpose(0, 2, 1, 3)                # (P, Hkv, page, D)
    vt = v_pages.transpose(0, 2, 1, 3)

    in_specs = [
        pl.BlockSpec((1, 1, G, D),
                     lambda b, h, t, bt, sl: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, page, D),
                     lambda b, h, t, bt, sl: (bt[b, t], h, 0, 0)),
        pl.BlockSpec((1, 1, page, Dv),
                     lambda b, h, t, bt, sl: (bt[b, t], h, 0, 0)),
    ]
    operands = [qg, kt, vt]
    if quantized:
        in_specs += [pl.BlockSpec((1, page),
                                  lambda b, h, t, bt, sl: (bt[b, t], 0))] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, T),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, Dv),
                               lambda b, h, t, bt, sl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_kernel, scale=scale, page_size=page,
                               quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), seq_lens.astype(jnp.int32),
      *operands)
    return out.reshape(B, H, Dv)


def _paged_chunk_kernel(bt_ref, pos_ref, nv_ref, q_ref, k_ref, v_ref, *rest,
                        scale, page_size, G, quantized=False):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]
    seq_len = pos + nv_ref[b]         # this lane's live history (keys < it)
    k_start = it * page_size          # logical position of this page's slot 0

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (C*G, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (page, D)
        if quantized:
            k = k * ks_ref[0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (C*G, page)
        # row r is chunk lane c = r // G at logical position pos + c: causal
        # within the chunk (k_pos <= q_pos) over live keys (k_pos < seq_len)
        q_pos = pos + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where((k_pos <= q_pos) & (k_pos < seq_len), s, NEG_INF)

        m_prev = m_scr[...]                               # (C*G,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        # rows with no visible key yet keep m == NEG_INF, where s - m == 0
        # would count every masked key: zero those weights explicitly so the
        # no-visible-key rows emit 0 (the oracle's convention)
        p = jnp.where(m_cur[:, None] > NEG_INF / 2, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32)               # (page, Dv)
        if quantized:
            v = v * vs_ref[0].astype(jnp.float32)[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    # skip pages entirely past the lane's live history
    pl.when(k_start < seq_len)(_body)

    @pl.when(it == nt - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_chunk_attention(q, k_pages, v_pages, block_tables, pos, n_valid, *,
                          scale=None, k_scale=None, v_scale=None,
                          interpret=False):
    """Chunked paged attention — per-lane rectangular (B, C) layout.

    Kept as the padded reference the packed serving kernel
    (``paged_packed_attention``) is benchmarked against.

    q: (B, C, H, D) — lane b's C query tokens at logical positions
    ``pos[b] .. pos[b] + C - 1``, first ``n_valid[b]`` valid (their K/V are
    already scattered into the pools); k_pages/v_pages: (P, page, Hkv, D*);
    block_tables: (B, T) int32; pos/n_valid: (B,) int32 -> (B, C, H, Dv).
    Causal within the chunk.  Rows past ``n_valid`` are finite but
    MEANINGLESS (they attend whatever live history the lane has; rows with
    no visible key emit 0) — callers must only read each lane's first
    ``n_valid`` rows; the serving engine gathers the last valid one.
    """
    B, C, H, D = q.shape
    page, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    G = H // Hkv
    T = block_tables.shape[1]
    scale = D ** -0.5 if scale is None else scale
    quantized = k_scale is not None

    # (B, C, Hkv, G, D) -> (B, Hkv, C*G, D): one MXU tile per (lane, kv head)
    qg = q.reshape(B, C, Hkv, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, Hkv, C * G, D)
    kt = k_pages.transpose(0, 2, 1, 3)                # (P, Hkv, page, D)
    vt = v_pages.transpose(0, 2, 1, 3)

    in_specs = [
        pl.BlockSpec((1, 1, C * G, D),
                     lambda b, h, t, bt, ps, nv: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, page, D),
                     lambda b, h, t, bt, ps, nv: (bt[b, t], h, 0, 0)),
        pl.BlockSpec((1, 1, page, Dv),
                     lambda b, h, t, bt, ps, nv: (bt[b, t], h, 0, 0)),
    ]
    operands = [qg, kt, vt]
    if quantized:
        in_specs += [pl.BlockSpec((1, page),
                                  lambda b, h, t, bt, ps, nv:
                                  (bt[b, t], 0))] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, T),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, C * G, Dv),
                               lambda b, h, t, bt, ps, nv: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * G,), jnp.float32),
            pltpu.VMEM((C * G,), jnp.float32),
            pltpu.VMEM((C * G, Dv), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_chunk_kernel, scale=scale,
                               page_size=page, G=G, quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, C * G, Dv), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), pos.astype(jnp.int32),
      n_valid.astype(jnp.int32), *operands)
    return out.reshape(B, Hkv, C, G, Dv).transpose(0, 2, 1, 3, 4) \
        .reshape(B, C, H, Dv)


def _paged_packed_kernel(bt_ref, sl_ref, ps_ref, q_ref, k_ref, v_ref, *rest,
                         scale, page_size, quantized=False):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    t = pl.program_id(0)
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = ps_ref[t]                 # -1 for padding tokens (nothing visible)
    k_start = it * page_size          # logical position of this page's slot 0

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (page, D)
        if quantized:
            k = k * ks_ref[0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, page)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

        m_prev = m_scr[...]                               # (G,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32)               # (page, Dv)
        if quantized:
            v = v * vs_ref[0].astype(jnp.float32)[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    # skip pages entirely past this token's position; padding tokens
    # (q_pos == -1) skip every page, so l stays 0 and the row emits 0
    pl.when(k_start <= q_pos)(_body)

    @pl.when(it == nt - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_packed_attention(q, k_pages, v_pages, block_tables, tok_slot,
                           tok_pos, *, scale=None, k_scale=None,
                           v_scale=None, interpret=False):
    """Packed ragged paged attention — the token-packed serving kernel.

    q: (T, H, D) — one flat buffer of query tokens where token t belongs
    to lane ``tok_slot[t]`` at logical position ``tok_pos[t]`` (its K/V
    already scattered into the pools); k_pages/v_pages: (P, page, Hkv, D*);
    block_tables: (S, Tb) int32 per-SLOT tables; tok_slot/tok_pos: (T,)
    int32.  Returns (T, H, Dv).

    Grid (T, Hkv, Tb): the K/V BlockSpec index maps read the block table
    through the scalar-prefetched per-token slot ids
    (``bt[tok_slot[t], j]``), so each grid step DMAs exactly one physical
    page of the token's OWN segment — the per-token generalisation of
    ``paged_decode_attention``'s per-lane indirection.  Pages past a
    token's position are skipped; padding tokens carry tok_pos == -1 and
    emit exactly 0 (same convention as the oracle).
    """
    T, H, D = q.shape
    page, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    G = H // Hkv
    Tb = block_tables.shape[1]
    scale = D ** -0.5 if scale is None else scale
    quantized = k_scale is not None

    qg = q.reshape(T, Hkv, G, D)
    kt = k_pages.transpose(0, 2, 1, 3)                # (P, Hkv, page, D)
    vt = v_pages.transpose(0, 2, 1, 3)

    in_specs = [
        pl.BlockSpec((1, 1, G, D),
                     lambda t, h, j, bt, sl, ps: (t, h, 0, 0)),
        pl.BlockSpec((1, 1, page, D),
                     lambda t, h, j, bt, sl, ps: (bt[sl[t], j], h, 0, 0)),
        pl.BlockSpec((1, 1, page, Dv),
                     lambda t, h, j, bt, sl, ps: (bt[sl[t], j], h, 0, 0)),
    ]
    operands = [qg, kt, vt]
    if quantized:
        in_specs += [pl.BlockSpec((1, page),
                                  lambda t, h, j, bt, sl, ps:
                                  (bt[sl[t], j], 0))] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(T, Hkv, Tb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, Dv),
                               lambda t, h, j, bt, sl, ps: (t, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_packed_kernel, scale=scale,
                               page_size=page, quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, Hkv, G, Dv), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), tok_slot.astype(jnp.int32),
      tok_pos.astype(jnp.int32), *operands)
    return out.reshape(T, H, Dv)


def _page_copy_kernel(src_ref, dst_ref, x_ref, o_ref):
    del src_ref, dst_ref
    o_ref[...] = x_ref[...]


def page_copy(pool, src, dst, *, interpret=False):
    """Copy-on-write page duplication inside one KV pool: the full rows of
    pages ``src`` (n,) are copied over pages ``dst`` (n,) in place.

    pool: (P, page, ...) — any paged pool layout (K, V, MLA latent, ...);
    src/dst: (n,) int32 physical page ids.  ``dst`` pages must be distinct
    freshly-allocated targets; ``src`` pages may repeat.  Returns the pool
    with the n page rows rewritten — the pool buffer is aliased into the
    output (``input_output_aliases``), so pages outside ``dst`` are
    untouched bytes, not recomputed copies.

    Grid (n,): ``src``/``dst`` ride in as scalar-prefetch operands and the
    in/out BlockSpec index maps address page ``src[i]`` / ``dst[i]``
    directly, so each grid step is exactly one page-row DMA through VMEM —
    the device-side memcpy behind ``BlockTable`` copy-on-write.
    """
    P, page = pool.shape[0], pool.shape[1]
    tail = 1
    for d in pool.shape[2:]:
        tail *= d
    flat = pool.reshape(P, page, tail)
    n = src.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, page, tail), lambda i, s, d: (s[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, page, tail), lambda i, s, d: (d[i], 0, 0)),
    )
    out = pl.pallas_call(
        _page_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(flat.shape, flat.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(src.astype(jnp.int32), dst.astype(jnp.int32), flat)
    return out.reshape(pool.shape)
