"""Mixture-of-Experts FFN.

Two execution paths:

* ``moe_apply`` (pure jnp): capacity-based top-k dispatch via one-hot cumsum +
  scatter/gather.  Used on a single device (smoke tests), for decode (token
  counts are tiny), and as the *oracle* for the sharded path.
* ``moe_apply_sharded`` (shard_map): expert parallelism over the ``model``
  mesh axis with explicit ``jax.lax.all_to_all`` dispatch/return — the
  production train path.  Collective bytes are visible in the lowered HLO and
  feed the roofline's ICI term.

Routing: softmax top-k with renormalisation, capacity factor ``cf`` (tokens
above capacity are dropped — standard fixed-shape TPU practice; recorded as a
deviation from DeepSeek's dropless routing in DESIGN.md §7).  Aux
load-balance loss per Switch/DeepSeek: ``E * sum_e f_e * P_e``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.compat import shard_map
import numpy as np

from repro.models import layers as L


# ------------------------------------------------------------------------- #
# init
# ------------------------------------------------------------------------- #
def moe_init(key, cfg):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "router": jax.random.normal(ks[0], (d, E), pd) * s_in,
        "wi": jax.random.normal(ks[1], (E, d, f), pd) * s_in,
        "wg": jax.random.normal(ks[2], (E, d, f), pd) * s_in,
        "wo": jax.random.normal(ks[3], (E, f, d), pd) * s_out,
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, f * cfg.n_shared_experts,
                                 "swiglu", cfg.param_dtype)
    return p


def _capacity(T, k, E, cf):
    return max(4, int(math.ceil(T * k / E * cf)))


# ------------------------------------------------------------------------- #
# routing + dispatch plumbing (shared by both paths)
# ------------------------------------------------------------------------- #
def _route(p, cfg, x2d):
    """x2d: (T, d) -> (weights (T,k), experts (T,k), aux_loss)."""
    logits = (x2d @ p["router"].astype(x2d.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    E = cfg.n_experts
    if cfg.route_groups:
        # group-limited routing (DeepSeek-V3 node-limited top-k): keep only
        # the top `route_group_limit` groups per token (group score = sum of
        # top-2 affinities within the group), mask the rest.
        G = cfg.route_groups
        pg = probs.reshape(-1, G, E // G)
        top2 = jax.lax.top_k(pg, min(2, E // G))[0].sum(-1)       # (T, G)
        _, gidx = jax.lax.top_k(top2, cfg.route_group_limit)      # (T, L)
        gmask = jnp.zeros_like(top2).at[
            jnp.arange(top2.shape[0])[:, None], gidx].set(1.0)
        probs = (pg * gmask[:, :, None]).reshape(-1, E)
    w, e = jax.lax.top_k(probs, cfg.top_k)                  # (T, k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    # load-balance aux: fraction routed vs mean prob
    f_e = jnp.mean(jax.nn.one_hot(e, E, dtype=jnp.float32).sum(1), 0)  # (E,)
    P_e = jnp.mean(probs, 0)
    aux = E * jnp.sum(f_e * P_e)
    return w.astype(x2d.dtype), e, aux


def _dispatch_indices(e, k, E, C):
    """e: (T, k) expert ids -> (e_flat, pos, valid) each (T*k,)."""
    ef = e.reshape(-1)                                       # (N,) token-major
    onehot = jax.nn.one_hot(ef, E, dtype=jnp.int32)          # (N, E)
    cum = jnp.cumsum(onehot, axis=0) - onehot                # exclusive
    pos = jnp.take_along_axis(cum, ef[:, None], axis=1)[:, 0]
    valid = pos < C
    return ef, jnp.where(valid, pos, C - 1), valid


def _expert_ffn(wi, wg, wo, xs):
    """xs: (E_loc, C*, d); w*: (E_loc, d, f)/(E_loc, f, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wg)) * \
        jnp.einsum("ecd,edf->ecf", xs, wi)
    return jnp.einsum("ecf,efd->ecd", h, wo)


# ------------------------------------------------------------------------- #
# pure-jnp path (single device / decode / oracle)
# ------------------------------------------------------------------------- #
def moe_apply(p, cfg, x):
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    T, k, E = B * S, cfg.top_k, cfg.n_experts
    C = _capacity(T, k, E, cfg.capacity_factor)
    x2d = x.reshape(T, d)
    w, e, aux = _route(p, cfg, x2d)
    ef, pos, valid = _dispatch_indices(e, k, E, C)
    tok = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[ef, pos].add(x2d[tok] * valid[:, None].astype(x.dtype))
    out_buf = _expert_ffn(p["wi"].astype(x.dtype), p["wg"].astype(x.dtype),
                          p["wo"].astype(x.dtype), buf)
    gathered = out_buf[ef, pos] * valid[:, None].astype(x.dtype)  # (N, d)
    y = jnp.sum(gathered.reshape(T, k, d) * w[..., None], axis=1)
    if "shared" in p:
        y = y + L.mlp_apply(p["shared"], x2d, "swiglu")
    return y.reshape(B, S, d), aux


# ------------------------------------------------------------------------- #
# explicit-TP partial-sum path (inside model.decoder_stack_tp's shard_map)
# ------------------------------------------------------------------------- #
def moe_apply_partial(p, cfg, x, axis="model"):
    """Local-expert PARTIAL sum — runs INSIDE an enclosing shard_map.

    ``p`` holds this device's shards: wi/wg/wo are (E_loc, ...) expert slices
    (experts over the model axis), the router is replicated, and the shared
    expert (if any) is column/row-sharded like a dense TP MLP.  ``x`` is the
    replicated-over-model activation.  Every device routes all tokens with
    the full router, but computes only the experts it owns; tokens whose
    experts live elsewhere contribute zero here.  The sum over the model
    axis of the returned tensor equals ``moe_apply`` — so the block-level
    psum that assembles the MLP (or the fused MHA+MLP psum under fal) also
    completes the expert combine, with no all-to-all at all.

    x: (B, S, d) -> (y_partial, aux).  ``aux`` is replicated (routing sees
    identical inputs on every device)."""
    B, S, d = x.shape
    E, E_loc = cfg.n_experts, p["wi"].shape[0]
    T, k = B * S, cfg.top_k
    C = _capacity(T, k, E, cfg.capacity_factor)
    x2d = x.reshape(T, d)
    w, e, aux = _route({"router": p["router"]}, cfg, x2d)
    ef, pos, valid = _dispatch_indices(e, k, E, C)
    tok = jnp.repeat(jnp.arange(T), k)
    lo = jax.lax.axis_index(axis) * E_loc if E_loc != E else 0
    mine = (ef >= lo) & (ef < lo + E_loc)
    ok = valid & mine
    ef_loc = jnp.where(mine, ef - lo, 0)
    buf = jnp.zeros((E_loc, C, d), x.dtype)
    buf = buf.at[ef_loc, pos].add(x2d[tok] * ok[:, None].astype(x.dtype))
    out_buf = _expert_ffn(p["wi"].astype(x.dtype), p["wg"].astype(x.dtype),
                          p["wo"].astype(x.dtype), buf)
    gathered = out_buf[ef_loc, pos] * ok[:, None].astype(x.dtype)
    y = jnp.sum(gathered.reshape(T, k, d) * w[..., None], axis=1)
    if "shared" in p:
        # the shared expert arrives as a TP shard (wi/wg column, wo row):
        # mlp_apply over it is itself a partial sum — fuses into the psum
        y = y + L.mlp_apply(p["shared"], x2d, "swiglu")
    return y.reshape(B, S, d), aux


# ------------------------------------------------------------------------- #
# shard_map expert-parallel path (training)
# ------------------------------------------------------------------------- #
def moe_apply_sharded(p, cfg, x, plan):
    """Expert parallelism: experts sharded over ``plan.model_axis``; tokens
    all-to-all'd to expert owners and back.  x: (B, S, d) global."""
    from jax.sharding import PartitionSpec as P

    mesh = plan.mesh
    data_axes, model_axis = tuple(plan.data_axes), plan.model_axis
    M = mesh.shape[model_axis]
    E = cfg.n_experts
    assert E % M == 0, (E, M)

    def local_fn(router, wi, wg, wo, shared, x_loc):
        # x_loc: (b, S/M, d) — tokens are sharded over the model axis too
        # (replicating them would duplicate routing + expert compute x M,
        # EXPERIMENTS.md §Perf D4)
        b, S, d = x_loc.shape
        T, k = b * S, cfg.top_k
        C = _capacity(T, k, E, cfg.capacity_factor)
        x2d = x_loc.reshape(T, d)
        pl = {"router": router}
        w, e, aux = _route(pl, cfg, x2d)
        ef, pos, valid = _dispatch_indices(e, k, E, C)
        tok = jnp.repeat(jnp.arange(T), k)
        buf = jnp.zeros((E, C, d), x_loc.dtype)
        buf = buf.at[ef, pos].add(x2d[tok] * valid[:, None].astype(x_loc.dtype))
        # dispatch: (E, C, d) -> (M, E_loc, C, d) -> A2A -> src-major buffer
        buf = buf.reshape(M, E // M, C, d)
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        # local experts over all sources' tokens
        xs = buf.transpose(1, 0, 2, 3).reshape(E // M, M * C, d)
        ys = _expert_ffn(wi.astype(x_loc.dtype), wg.astype(x_loc.dtype),
                         wo.astype(x_loc.dtype), xs)
        ys = ys.reshape(E // M, M, C, d).transpose(1, 0, 2, 3)
        ys = jax.lax.all_to_all(ys, model_axis, split_axis=0, concat_axis=0,
                                tiled=False)
        out_buf = ys.reshape(E, C, d)
        gathered = out_buf[ef, pos] * valid[:, None].astype(x_loc.dtype)
        y = jnp.sum(gathered.reshape(T, k, d) * w[..., None], axis=1)
        if shared is not None:
            y = y + L.mlp_apply(shared, x2d, "swiglu")
        aux = jax.lax.pmean(aux, data_axes + (model_axis,))
        return y.reshape(b, S, d), aux

    shared = p.get("shared")
    in_specs = (P(), P(model_axis), P(model_axis), P(model_axis),
                None if shared is None else jax.tree.map(lambda _: P(), shared),
                P(data_axes, model_axis, None))
    out_specs = (P(data_axes, model_axis, None), P())
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(p["router"], p["wi"], p["wg"], p["wo"], shared, x)


# ------------------------------------------------------------------------- #
# shard-slot dispatch (beyond-paper, EXPERIMENTS.md §Perf D3)
# ------------------------------------------------------------------------- #
def moe_apply_shard_slot(p, cfg, x, plan):
    """Expert parallelism with ONE wire crossing per (token, destination
    shard) instead of one per (token, expert).

    With top-8 token-choice dispatch, the per-expert capacity buffer ships
    each token up to 8x (+ capacity padding).  Group-limited routing
    (cfg.route_groups aligned to the expert shards, limit L) bounds each
    token to L destination shards; tokens are packed into per-shard slots
    (M, C_shard, d), all-to-all'd ONCE, then dispatched to local experts on
    the receiving side.  Payload drops from k*cf to ~L*cf' copies.
    """
    from jax.sharding import PartitionSpec as P

    mesh = plan.mesh
    data_axes, model_axis = tuple(plan.data_axes), plan.model_axis
    M = mesh.shape[model_axis]
    E = cfg.n_experts
    L = cfg.route_group_limit if cfg.route_groups else min(cfg.top_k, M)
    assert E % M == 0

    def local_fn(router, wi, wg, wo, shared, x_loc):
        # x_loc: (b, S/M, d) — sequence sharded over model (§Perf D4)
        b, S, d = x_loc.shape
        T, k = b * S, cfg.top_k
        E_loc = E // M
        Cs = _capacity(T, L, M, cfg.capacity_factor)   # slots per dest shard
        x2d = x_loc.reshape(T, d)
        w, e, aux = _route({"router": router}, cfg, x2d)

        # destination shard per (token, k-slot); dedupe to per-token shard
        # slots: shard s needed iff any expert maps to it
        dest = e // E_loc                                       # (T, k)
        need = jnp.zeros((T, M), jnp.int32).at[
            jnp.arange(T)[:, None], dest].set(1)                # (T, M)
        # position of token t in shard s's send buffer (exclusive cumsum)
        pos = jnp.cumsum(need, axis=0) - need                   # (T, M)
        valid = (pos < Cs) & (need > 0)
        pos_c = jnp.where(valid, pos, Cs - 1)

        # pack send buffer (M, Cs, d); dropped/overflow slots scatter
        # out-of-bounds with mode="drop"
        pos_oob = jnp.where(valid, pos, Cs)
        send = jnp.zeros((M, Cs, d), x_loc.dtype)
        tok_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, M))
        send = send.at[jnp.broadcast_to(jnp.arange(M)[None], (T, M)),
                       pos_oob].add(
            x2d[tok_idx] * valid[..., None].astype(x_loc.dtype),
            mode="drop")
        recv = jax.lax.all_to_all(send, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (M_src, Cs, d) — tokens this shard must process

        # metadata: per (token, k-slot) local expert id + weight, packed the
        # same way (tiny payload: ints + floats)
        le = e % E_loc                                          # (T, k)
        meta_e = jnp.full((M, Cs, k), -1, jnp.int32)
        meta_w = jnp.zeros((M, Cs, k), jnp.float32)
        kslot = jnp.broadcast_to(jnp.arange(k)[None], (T, k))
        vslot = jnp.take_along_axis(valid, dest, axis=1)        # (T, k)
        pslot = jnp.where(vslot,
                          jnp.take_along_axis(pos_oob, dest, axis=1), Cs)
        meta_e = meta_e.at[dest, pslot, kslot].set(le, mode="drop")
        meta_w = meta_w.at[dest, pslot, kslot].set(
            w.astype(jnp.float32), mode="drop")
        meta_e = jax.lax.all_to_all(meta_e, model_axis, 0, 0, tiled=False)
        meta_w = jax.lax.all_to_all(meta_w, model_axis, 0, 0, tiled=False)

        # local second-stage dispatch: (M_src*Cs) tokens -> E_loc experts
        N = M * Cs
        xs = recv.reshape(N, d)
        ef = meta_e.reshape(N, k)
        wf = meta_w.reshape(N, k).astype(x_loc.dtype)
        # expected per-local-expert load: every source shard contributes
        # ~T*k/E tokens per expert; N is mostly padding — size on that.
        C2 = _capacity(M * T, k, E, cfg.capacity_factor) * 2
        ef_flat = jnp.where(ef >= 0, ef, 0).reshape(-1)
        onehot = jax.nn.one_hot(ef_flat, E_loc, dtype=jnp.int32) * \
            (ef.reshape(-1) >= 0)[:, None]
        cum = jnp.cumsum(onehot, axis=0) - onehot
        pos2 = jnp.take_along_axis(cum, ef_flat[:, None], 1)[:, 0]
        ok2 = (ef.reshape(-1) >= 0) & (pos2 < C2)
        pos2_oob = jnp.where(ok2, pos2, C2)
        tok2 = jnp.repeat(jnp.arange(N), k)
        buf = jnp.zeros((E_loc, C2, d), x_loc.dtype)
        buf = buf.at[ef_flat, pos2_oob].add(
            xs[tok2] * ok2[:, None].astype(x_loc.dtype), mode="drop")
        out_buf = _expert_ffn(wi.astype(x_loc.dtype), wg.astype(x_loc.dtype),
                              wo.astype(x_loc.dtype), buf)
        pos2c = jnp.where(ok2, pos2, C2 - 1)
        gath = out_buf[ef_flat, pos2c] * ok2[:, None].astype(x_loc.dtype)
        # weighted partial sum per received token (weights applied HERE)
        y_tok = jnp.sum(gath.reshape(N, k, d) * wf[..., None], axis=1)
        y_back = jax.lax.all_to_all(
            y_tok.reshape(M, Cs, d), model_axis, 0, 0,
            tiled=False)                                         # (M, Cs, d)

        # final combine: token t sums its <= M shard partials
        pos_rd = jnp.where(valid, pos, 0)
        parts = y_back[jnp.broadcast_to(jnp.arange(M)[None], (T, M)), pos_rd]
        y = jnp.sum(parts * valid[..., None].astype(x_loc.dtype), axis=1)
        if shared is not None:
            y = y + L_mlp(shared, x2d)
        aux = jax.lax.pmean(aux, data_axes + (model_axis,))
        return y.reshape(b, S, d), aux

    def L_mlp(shared, x2d):
        from repro.models import layers as LL
        return LL.mlp_apply(shared, x2d, "swiglu")

    shared = p.get("shared")
    in_specs = (P(), P(model_axis), P(model_axis), P(model_axis),
                None if shared is None else jax.tree.map(lambda _: P(), shared),
                P(data_axes, model_axis, None))
    out_specs = (P(data_axes, model_axis, None), P())
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(p["router"], p["wi"], p["wg"], p["wo"], shared, x)
