"""Transformer block assembly honoring the FAL connection modes (core/fal.py).

A block is:  x + MHA(ln1(x)) + FFN(mlp_input)   with optional gemma2-style
post-norms, MoE FFN, MLA attention, and cross-attention (whisper decoder).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fal
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M


def block_init(key, cfg, *, kind="dense", cross=False, is_block0=False):
    """kind: 'dense' (cfg.mlp FFN) | 'moe'.  cross adds cross-attention."""
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {"ln1": L.norm_init(d, cfg.norm, cfg.param_dtype),
         "ln2": L.norm_init(d, cfg.norm, cfg.param_dtype)}
    p["attn"] = (A.mla_init(ks[0], cfg) if cfg.use_mla
                 else A.gqa_init(ks[0], cfg))
    if cross:
        p["ln_x"] = L.norm_init(d, cfg.norm, cfg.param_dtype)
        p["xattn"] = A.gqa_init(ks[1], cfg, cross=True)
    if kind == "moe":
        p["ffn"] = M.moe_init(ks[2], cfg)
    else:
        d_ff = cfg.dense_d_ff or cfg.d_ff
        p["ffn"] = L.mlp_init(ks[2], d, d_ff, cfg.mlp, cfg.param_dtype)
    if cfg.connection in fal.NEEDS_LN_FAL and (
            not is_block0 or cfg.connection == "ablation1"):
        # ablation1 normalises each block's OWN attention — block 0 included
        p["ln_fal"] = L.norm_init(d, cfg.norm, cfg.param_dtype)
    if is_block0 and cfg.connection == "fal":
        p["ln_a"] = L.norm_init(d, cfg.norm, cfg.param_dtype)  # footnote 3
    if cfg.post_norms:
        p["post_attn"] = L.norm_init(d, cfg.norm, cfg.param_dtype)
        p["post_ffn"] = L.norm_init(d, cfg.norm, cfg.param_dtype)
    return p


def _tp_axis(parallel_ctx):
    """Mesh axis name when running INSIDE the explicit-TP shard_map
    (model.decoder_stack_tp); None on the replicated / GSPMD paths."""
    return parallel_ctx.get("tp_axis") if parallel_ctx else None


def _assemble(partial, axis):
    """All-reduce a TP partial sum over ``axis``; identity when replicated.
    tp_size = 1 is the degenerate psum — one code path, not two."""
    return jax.lax.psum(partial, axis) if axis is not None else partial


def _ffn_apply(p, cfg, h, kind, parallel_ctx, mode):
    """Returns (y, aux).  Under explicit TP ``y`` is a PARTIAL sum (dense:
    column-sharded wi/wg, row-sharded wo; MoE: local experts only)."""
    if kind == "moe":
        axis = _tp_axis(parallel_ctx)
        if axis is not None:
            return M.moe_apply_partial(p["ffn"], cfg, h, axis)
        if (parallel_ctx is not None and mode == "train"
                and parallel_ctx.get("mesh") is not None):
            fn = (M.moe_apply_shard_slot if cfg.route_groups
                  else M.moe_apply_sharded)
            return fn(p["ffn"], cfg, h,
                      parallel_ctx["mesh"],
                      parallel_ctx["data_axes"],
                      parallel_ctx["model_axis"])
        return M.moe_apply(p["ffn"], cfg, h)
    return L.mlp_apply(p["ffn"], h, cfg.mlp), jnp.zeros((), jnp.float32)


def block_apply(p, cfg, x, a1_sig, positions, window, *, kind="dense",
                is_block0=False, parallel_ctx=None, mode="train",
                enc_out=None, cache=None, pos=None, causal=True,
                block_tables=None, n_valid=None):
    """One block, full-sequence (train/prefill), single-token decode, or
    chunked paged decode/prefill (mode='paged': x is (B, C, D), ``cache`` a
    page pool, ``block_tables``/``n_valid`` the paged-serving metadata).

    Returns (x_out, a_raw, aux, new_cache).  ``a_raw`` is this block's MHA
    output (block 0 exports it as the first-attention signal).

    Inside the explicit-TP shard_map (``parallel_ctx["tp_axis"]`` set) the
    attention and FFN kernels see head-/hidden-/expert-sharded weights and
    return PARTIAL sums; this function owns the paper's collective
    structure: modes whose MLP input needs this block's assembled attention
    (``fal.attention_must_assemble``) pay two all-reduces, everything else
    adds the MHA and MLP partials locally and pays ONE fused all-reduce
    (Fig 2's 2 -> 1 halving).  With tp_size = 1 the psums are identity and
    this is exactly the replicated path — one code path for the family.
    ``a_raw`` is a partial sum on the fused path (no fused-path caller
    consumes it: fal/falplus block 0 always assemble).
    """
    h = L.norm_apply(p["ln1"], x, cfg.norm)
    new_cache = None
    if mode == "paged":
        if cfg.use_mla:
            a, new_cache = A.mla_paged_apply(p["attn"], cfg, h, cache,
                                             block_tables, pos, n_valid)
        else:
            a, new_cache = A.gqa_paged_apply(p["attn"], cfg, h, cache,
                                             block_tables, pos, n_valid,
                                             window=window)
    elif mode == "decode":
        if cfg.use_mla:
            a, new_cache = A.mla_decode(p["attn"], cfg, h, cache, pos)
        else:
            a, new_cache = A.gqa_decode(p["attn"], cfg, h, cache, pos,
                                        window=window)
    else:
        if cfg.use_mla:
            a = A.mla_apply(p["attn"], cfg, h, positions,
                            pctx=parallel_ctx)
        else:
            a = A.gqa_apply(p["attn"], cfg, h, positions, window=window,
                            causal=causal, pctx=parallel_ctx)
    axis = _tp_axis(parallel_ctx)
    # post-norms and cross-attention normalise/consume the true ``a`` —
    # nonlinear in the partial, so they force the assembled path
    fused = (axis is not None and not cfg.post_norms and "xattn" not in p
             and not fal.attention_must_assemble(cfg.connection, is_block0))

    if fused:
        # MLP input is independent of this block's attention: add the MHA
        # and MLP partial sums locally, assemble both in ONE all-reduce
        if is_block0:
            mlp_in = fal.block0_mlp_input(cfg, p, x, a)
        else:
            mlp_in = fal.mlp_input(cfg, p, x, a, a1_sig)
        y, aux = _ffn_apply(p, cfg, mlp_in, kind, parallel_ctx, mode)
        return x + _assemble(a + y, axis), a, aux, new_cache

    a = _assemble(a, axis)
    if cfg.post_norms:
        a = L.norm_apply(p["post_attn"], a, cfg.norm)

    resid = x + a

    if "xattn" in p:  # whisper decoder cross-attention
        cx = _assemble(
            A.gqa_cross_apply(p["xattn"], cfg,
                              L.norm_apply(p["ln_x"], resid, cfg.norm),
                              enc_out), axis)
        resid = resid + cx
        x = x + cx  # the FAL mlp_input uses x without self-attn but with cross

    if is_block0:
        mlp_in = fal.block0_mlp_input(cfg, p, x, a)
    else:
        mlp_in = fal.mlp_input(cfg, p, x, a, a1_sig)

    y, aux = _ffn_apply(p, cfg, mlp_in, kind, parallel_ctx, mode)
    y = _assemble(y, axis)
    if cfg.post_norms:
        y = L.norm_apply(p["post_ffn"], y, cfg.norm)
    return resid + y, a, aux, new_cache


def window_schedule(cfg, n_layers=None):
    """Per-layer sliding windows.  gemma2: alternate local/global."""
    n = n_layers or cfg.n_layers
    if cfg.layer_pattern == "local_global" and cfg.sliding_window:
        return [cfg.sliding_window if i % 2 == 0 else 0 for i in range(n)]
    return [cfg.sliding_window] * n
