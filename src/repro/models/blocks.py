"""Transformer block assembly honoring the FAL connection modes (core/fal.py).

A block is:  x + MHA(ln1(x)) + FFN(mlp_input)   with optional gemma2-style
post-norms, MoE FFN, MLA attention, and cross-attention (whisper decoder).

Execution is driven by an ``ExecutionPlan`` (core/plan.py): ``plan.phase``
picks the full-sequence / decode / paged attention path, and inside the
explicit-TP shard_map (``plan.tp_axis`` set) this module owns the paper's
per-block collective structure — including the Megatron-SP sequence-parallel
variant (``plan.sequence_parallel``) where the residual stream between
blocks stays sharded over the model axis along the sequence dimension and
every all-reduce becomes a reduce-scatter (1/tp the reduce bytes) paired
with an all-gather around the LN regions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fal
from repro.core.plan import ExecutionPlan, Phase
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.optim import grad_compress as GC


def block_init(key, cfg, *, kind="dense", cross=False, is_block0=False):
    """kind: 'dense' (cfg.mlp FFN) | 'moe'.  cross adds cross-attention."""
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {"ln1": L.norm_init(d, cfg.norm, cfg.param_dtype),
         "ln2": L.norm_init(d, cfg.norm, cfg.param_dtype)}
    p["attn"] = (A.mla_init(ks[0], cfg) if cfg.use_mla
                 else A.gqa_init(ks[0], cfg))
    if cross:
        p["ln_x"] = L.norm_init(d, cfg.norm, cfg.param_dtype)
        p["xattn"] = A.gqa_init(ks[1], cfg, cross=True)
    if kind == "moe":
        p["ffn"] = M.moe_init(ks[2], cfg)
    else:
        d_ff = cfg.dense_d_ff or cfg.d_ff
        p["ffn"] = L.mlp_init(ks[2], d, d_ff, cfg.mlp, cfg.param_dtype)
    if cfg.connection in fal.NEEDS_LN_FAL and (
            not is_block0 or cfg.connection == "ablation1"):
        # ablation1 normalises each block's OWN attention — block 0 included
        p["ln_fal"] = L.norm_init(d, cfg.norm, cfg.param_dtype)
    if is_block0 and cfg.connection == "fal":
        p["ln_a"] = L.norm_init(d, cfg.norm, cfg.param_dtype)  # footnote 3
    if cfg.post_norms:
        p["post_attn"] = L.norm_init(d, cfg.norm, cfg.param_dtype)
        p["post_ffn"] = L.norm_init(d, cfg.norm, cfg.param_dtype)
    return p


def _assemble(partial, axis, compress="none"):
    """All-reduce a TP partial sum over ``axis``; identity when replicated.
    tp_size = 1 is the degenerate psum — one code path, not two.

    ``compress`` (``plan.grad_compress``) selects the BACKWARD collective:
    'none' is a plain psum (its transpose — the TP gradient all-reduce —
    stays exact fp32, byte-identical HLO to before the knob existed);
    'int8'/'lowrank' route the cotangent through
    ``optim.grad_compress.compressed_psum`` — forward still exact."""
    if axis is None:
        return partial
    if compress != "none":
        return GC.compressed_psum(partial, axis, compress)
    return jax.lax.psum(partial, axis)


def _ffn_apply(p, cfg, h, kind, plan: ExecutionPlan):
    """Returns (y, aux).  Under explicit TP ``y`` is a PARTIAL sum (dense:
    column-sharded wi/wg, row-sharded wo; MoE: local experts only)."""
    if kind == "moe":
        axis = plan.tp_axis
        if axis is not None:
            return M.moe_apply_partial(p["ffn"], cfg, h, axis)
        if plan.is_training_like and plan.is_sharded:
            fn = (M.moe_apply_shard_slot if cfg.route_groups
                  else M.moe_apply_sharded)
            return fn(p["ffn"], cfg, h, plan)
        return M.moe_apply(p["ffn"], cfg, h)
    return L.mlp_apply(p["ffn"], h, cfg.mlp), jnp.zeros((), jnp.float32)


def block_apply(p, cfg, x, a1_sig, positions, window, *, kind="dense",
                is_block0=False, plan=None, enc_out=None, cache=None,
                pos=None, causal=True, block_tables=None, n_valid=None,
                tok_slot=None, tok_pos=None):
    """One block, full-sequence (train/eval/prefill), single-token decode,
    or paged decode/prefill (``plan.phase``; ``cache`` a page pool).  Paged
    has two layouts: the padded chunk layout — x (B, C, D) with per-lane
    ``pos``/``n_valid`` — and the token-PACKED layout — x (1, T, D) one
    flat ragged buffer with per-token ``tok_slot``/``tok_pos`` segment ids
    (selected when ``tok_slot`` is not None; the serving engine's tick).

    Returns (x_out, a_raw, aux, new_cache).  ``a_raw`` is this block's MHA
    output (block 0 exports it as the first-attention signal).

    Inside the explicit-TP shard_map (``plan.tp_axis`` set) the attention
    and FFN kernels see head-/hidden-/expert-sharded weights and return
    PARTIAL sums; this function owns the paper's collective structure:
    modes whose MLP input needs this block's assembled attention
    (``fal.attention_must_assemble``) pay two all-reduces, everything else
    adds the MHA and MLP partials locally and pays ONE fused all-reduce
    (Fig 2's 2 -> 1 halving).  With tp_size = 1 the psums are identity and
    this is exactly the replicated path — one code path for the family.
    ``a_raw`` is a partial sum on the fused path (no fused-path caller
    consumes it: fal/falplus block 0 always assemble).

    With ``plan.sequence_parallel`` the same fork runs in the Megatron-SP
    layout (``_block_apply_sp``): x arrives sharded (B, S/tp, D) along the
    sequence over the model axis and every all-reduce above becomes a
    reduce-scatter (1/tp the bytes) behind an all-gather of the LN region.
    """
    plan = ExecutionPlan.resolve(plan)
    if plan.dual_branch and not is_block0 \
            and plan.phase in (Phase.DECODE, Phase.PAGED):
        # steady-state MHA||MLP branch parallelism (plan.validate guarantees
        # a DUAL_BRANCH_MODES connection and no post-norms); block 0 stays
        # sequential — it must assemble its attention to export the signal
        if "xattn" in p:
            raise NotImplementedError(
                "dual-branch decode supports self-attention decoder blocks "
                "only (cross-attention consumes the assembled attention)")
        return _block_apply_dual(p, cfg, x, a1_sig, window, kind=kind,
                                 plan=plan, cache=cache, pos=pos,
                                 block_tables=block_tables, n_valid=n_valid,
                                 tok_slot=tok_slot, tok_pos=tok_pos)
    if plan.sequence_parallel and plan.tp_axis is not None \
            and plan.full_sequence:
        if "xattn" in p or not causal:
            # cross-attention consumes the assembled attention and the
            # encoder stacks are bidirectional — neither has an SP layout;
            # refuse rather than silently fuse/skip them
            raise NotImplementedError(
                "sequence-parallel blocks support causal self-attention "
                "only (no cross-attention / bidirectional encoders)")
        return _block_apply_sp(p, cfg, x, a1_sig, positions, window,
                               kind=kind, is_block0=is_block0, plan=plan)

    h = L.norm_apply(p["ln1"], x, cfg.norm)
    new_cache = None
    if plan.phase is Phase.PAGED:
        if tok_slot is not None:
            if cfg.use_mla:
                a, new_cache = A.mla_packed_apply(p["attn"], cfg, h, cache,
                                                  block_tables, tok_slot,
                                                  tok_pos)
            else:
                a, new_cache = A.gqa_packed_apply(p["attn"], cfg, h, cache,
                                                  block_tables, tok_slot,
                                                  tok_pos, window=window)
        elif cfg.use_mla:
            a, new_cache = A.mla_paged_apply(p["attn"], cfg, h, cache,
                                             block_tables, pos, n_valid)
        else:
            a, new_cache = A.gqa_paged_apply(p["attn"], cfg, h, cache,
                                             block_tables, pos, n_valid,
                                             window=window)
    elif plan.phase is Phase.DECODE:
        if cfg.use_mla:
            a, new_cache = A.mla_decode(p["attn"], cfg, h, cache, pos)
        else:
            a, new_cache = A.gqa_decode(p["attn"], cfg, h, cache, pos,
                                        window=window)
    else:
        if cfg.use_mla:
            a = A.mla_apply(p["attn"], cfg, h, positions, plan=plan)
        else:
            a = A.gqa_apply(p["attn"], cfg, h, positions, window=window,
                            causal=causal, plan=plan)
    axis = plan.tp_axis
    # post-norms and cross-attention normalise/consume the true ``a`` —
    # nonlinear in the partial, so they force the assembled path
    fused = (axis is not None and not cfg.post_norms and "xattn" not in p
             and not fal.attention_must_assemble(cfg.connection, is_block0))

    if fused:
        # MLP input is independent of this block's attention: add the MHA
        # and MLP partial sums locally, assemble both in ONE all-reduce
        if is_block0:
            mlp_in = fal.block0_mlp_input(cfg, p, x, a)
        else:
            mlp_in = fal.mlp_input(cfg, p, x, a, a1_sig)
        y, aux = _ffn_apply(p, cfg, mlp_in, kind, plan)
        return (x + _assemble(a + y, axis, plan.grad_compress),
                a, aux, new_cache)

    a = _assemble(a, axis, plan.grad_compress)
    if cfg.post_norms:
        a = L.norm_apply(p["post_attn"], a, cfg.norm)

    resid = x + a

    if "xattn" in p:  # whisper decoder cross-attention
        cx = _assemble(
            A.gqa_cross_apply(p["xattn"], cfg,
                              L.norm_apply(p["ln_x"], resid, cfg.norm),
                              enc_out), axis, plan.grad_compress)
        resid = resid + cx
        x = x + cx  # the FAL mlp_input uses x without self-attn but with cross

    if is_block0:
        mlp_in = fal.block0_mlp_input(cfg, p, x, a)
    else:
        mlp_in = fal.mlp_input(cfg, p, x, a, a1_sig)

    y, aux = _ffn_apply(p, cfg, mlp_in, kind, plan)
    y = _assemble(y, axis, plan.grad_compress)
    if cfg.post_norms:
        y = L.norm_apply(p["post_ffn"], y, cfg.norm)
    return resid + y, a, aux, new_cache


def _block_apply_dual(p, cfg, x, a1_sig, window, *, kind,
                      plan: ExecutionPlan, cache, pos, block_tables,
                      n_valid, tok_slot=None, tok_pos=None):
    """Branch-parallel decode block: MHA || MLP (``plan.dual_branch``).

    For ``core.fal.DUAL_BRANCH_MODES`` the MLP input is a function of only
    the residual stream and the (cached) first-attention signal — never this
    block's own attention — so the two branches share no data dependency:

        MLP branch : mlp_input(x, a1_sig) -> FFN            (MXU-bound)
        MHA branch : ln1(x) -> qkv -> paged KV gather -> wo (DMA-bound)

    This function forms the MLP input FIRST, so the FFN matmuls are never
    serialized behind the attention branch's block-table gather; on the
    paged C == 1 dense fast path both branches go down as ONE fused kernel
    dispatch (``attention.gqa_paged_dual`` ->
    ``kernels.ops.dual_branch_decode``) that overlaps page DMAs with FFN
    MXU work.  Off the fused-kernel path the arithmetic is op-for-op the
    sequential path's — same primitives, same operands, same residual-merge
    association — so logits are bit-identical (the fused TPU kernel's tiled
    accumulation is tolerance-close instead); under explicit TP the two
    partial sums merge in the SAME single fused all-reduce as the
    sequential fused path (no extra collectives; asserted structurally in
    ``core.tp.make_tp_decode_step`` consumers).
    """
    axis = plan.tp_axis
    # MLP branch input — depends on (x, a1_sig) only; `a=None` is safe
    # because DUAL_BRANCH_MODES never read the block's own attention
    mlp_in = fal.mlp_input(cfg, p, x, None, a1_sig)
    h = L.norm_apply(p["ln1"], x, cfg.norm)
    C = x.shape[1]
    if (plan.phase is Phase.PAGED and kind == "dense" and not cfg.use_mla
            and tok_slot is None and C == 1 and cfg.attn_softcap == 0.0
            and isinstance(window, int) and window == 0):
        # single-token dense tick: fused dual-branch dispatch (padded
        # layout only — a packed buffer of length 1 is NOT a (B, 1) tick)
        a, y, new_cache = A.gqa_paged_dual(p["attn"], p["ffn"], cfg, h,
                                           mlp_in, cache, block_tables,
                                           pos, n_valid)
        aux = jnp.zeros((), jnp.float32)
    else:
        if plan.phase is Phase.PAGED and tok_slot is not None:
            # token-packed tick: branch-parallel ops (the packed kernel
            # serves the MHA branch; arithmetic matches the sequential
            # packed path op-for-op, so tokens stay bit-identical)
            if cfg.use_mla:
                a, new_cache = A.mla_packed_apply(p["attn"], cfg, h, cache,
                                                  block_tables, tok_slot,
                                                  tok_pos)
            else:
                a, new_cache = A.gqa_packed_apply(p["attn"], cfg, h, cache,
                                                  block_tables, tok_slot,
                                                  tok_pos, window=window)
        elif plan.phase is Phase.PAGED:
            if cfg.use_mla:
                a, new_cache = A.mla_paged_apply(p["attn"], cfg, h, cache,
                                                 block_tables, pos, n_valid)
            else:
                a, new_cache = A.gqa_paged_apply(p["attn"], cfg, h, cache,
                                                 block_tables, pos, n_valid,
                                                 window=window)
        else:
            if cfg.use_mla:
                a, new_cache = A.mla_decode(p["attn"], cfg, h, cache, pos)
            else:
                a, new_cache = A.gqa_decode(p["attn"], cfg, h, cache, pos,
                                            window=window)
        y, aux = _ffn_apply(p, cfg, mlp_in, kind, plan)
    if axis is not None:
        # one fused collective per block, same as the sequential fused path
        return (x + _assemble(a + y, axis, plan.grad_compress),
                a, aux, new_cache)
    # replicated: keep the sequential path's (x + a) + y association so
    # dual-branch logits are bit-identical, not merely close
    return (x + a) + y, a, aux, new_cache


def _block_apply_sp(p, cfg, x_s, a1_sig, positions, window, *, kind,
                    is_block0, plan: ExecutionPlan):
    """Sequence-parallel (Megatron-SP) block inside the explicit-TP
    shard_map: ``x_s`` is the (B, S/tp, D) sequence shard of the residual
    stream; the output shard stays (B, S/tp, D).

    Collective structure per block (reduce ops map 1:1 onto the replicated
    path's all-reduces, at 1/tp the output bytes):

      fused (fal/parallel steady state):
          all-gather(x_s) -> attention + MLP partials over the full
          sequence -> ONE reduce-scatter(a + y) back to the shard.
      assembled (preln/falplus/ablations, post-norms):
          all-gather(x_s) -> attention partial -> reduce-scatter(a) ->
          sharded LN region forms mlp_input on the shard ->
          all-gather(mlp_input) -> MLP partial -> reduce-scatter(y).
      block 0 with a first-attention export (fal/falplus):
          the attention partial pays a true all-reduce instead of the
          reduce-scatter — the signal feeds EVERY later block at EVERY
          position, so it is the one tensor that must stay fully
          assembled and replicated (the paper's single extra collective,
          still paid exactly once for the whole depth).

    LayerNorms run per-token, so ln1/ln2/post-norms apply to sharded or
    gathered tensors interchangeably; the MLP/MoE kernels need the full
    sequence because their hidden/expert shards partial-sum over devices
    spanning ALL tokens.  MoE routing sees the identical gathered input on
    every device, so ``moe_apply_partial`` composes unchanged and the
    reduce-scatter completes the expert combine.
    """
    axis = plan.tp_axis
    shard = x_s.shape[1]

    def gather(v):
        return jax.lax.all_gather(v, axis, axis=1, tiled=True)

    def scatter(v):
        # plan.grad_compress routes the BACKWARD all-gather (the transpose
        # of this reduce-scatter) through the compressed exchange; 'none'
        # lowers the plain collective, byte-identical to before
        if plan.grad_compress != "none":
            return GC.compressed_psum_scatter(v, axis, plan.grad_compress)
        return jax.lax.psum_scatter(v, axis, scatter_dimension=1, tiled=True)

    def local_slice(full):
        i = jax.lax.axis_index(axis)
        return jax.lax.dynamic_slice_in_dim(full, i * shard, shard, axis=1)

    x = gather(x_s)                                    # (B, S, D)
    h = L.norm_apply(p["ln1"], x, cfg.norm)
    if cfg.use_mla:
        a = A.mla_apply(p["attn"], cfg, h, positions, plan=plan)
    else:
        a = A.gqa_apply(p["attn"], cfg, h, positions, window=window,
                        plan=plan)

    fused = not (cfg.post_norms
                 or fal.attention_must_assemble(cfg.connection, is_block0))
    if fused:
        if is_block0:
            mlp_in = fal.block0_mlp_input(cfg, p, x, a)
        else:
            mlp_in = fal.mlp_input(cfg, p, x, a, a1_sig)
        y, aux = _ffn_apply(p, cfg, mlp_in, kind, plan)
        return x_s + scatter(a + y), a, aux, None

    full_export = is_block0 and cfg.connection in fal.USES_FIRST_ATTENTION
    if full_export:
        # block 0's signal export: fully assemble (and post-norm) the
        # attention so every device holds the replicated a1_raw
        a = _assemble(a, axis, plan.grad_compress)
        if cfg.post_norms:
            a = L.norm_apply(p["post_attn"], a, cfg.norm)
        resid_s = x_s + local_slice(a)
        mlp_in = fal.block0_mlp_input(cfg, p, x, a)
    else:
        a_s = scatter(a)                               # complete, sharded
        if cfg.post_norms:
            a_s = L.norm_apply(p["post_attn"], a_s, cfg.norm)
        resid_s = x_s + a_s
        sig_s = local_slice(a1_sig) if a1_sig is not None else None
        if is_block0:
            mlp_in_s = fal.block0_mlp_input(cfg, p, x_s, a_s)
        else:
            mlp_in_s = fal.mlp_input(cfg, p, x_s, a_s, sig_s)
        mlp_in = gather(mlp_in_s)                      # LN region -> full

    y, aux = _ffn_apply(p, cfg, mlp_in, kind, plan)
    y_s = scatter(y)
    if cfg.post_norms:
        y_s = L.norm_apply(p["post_ffn"], y_s, cfg.norm)
    return resid_s + y_s, a, aux, None


def window_schedule(cfg, n_layers=None):
    """Per-layer sliding windows.  gemma2: alternate local/global."""
    n = n_layers or cfg.n_layers
    if cfg.layer_pattern == "local_global" and cfg.sliding_window:
        return [cfg.sliding_window if i % 2 == 0 else 0 for i in range(n)]
    return [cfg.sliding_window] * n
