"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD for train/prefill (within-chunk quadratic + sequential inter-chunk
state recurrence via ``lax.scan``), O(1)-state single-step update for decode.
FAL is inapplicable here (no MHA->MLP pair; DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N  # x, B, C (n_groups = 1)
    return d_inner, H, N, conv_dim


def mamba_init(key, cfg):
    d = cfg.d_model
    d_inner, H, N, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)
    in_dim = 2 * d_inner + 2 * N + H  # z, x, B, C, dt
    p = {
        "in_proj": jax.random.normal(ks[0], (d, in_dim), pd) / np.sqrt(d),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), pd) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), pd),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)).astype(pd),
        "D": jnp.ones((H,), pd),
        "dt_bias": jnp.zeros((H,), pd) + jnp.log(jnp.expm1(0.01)).astype(pd),
        "norm": L.norm_init(d_inner, "rmsnorm", cfg.param_dtype),
        "out_proj": jax.random.normal(ks[2], (d_inner, d), pd) / np.sqrt(d_inner),
    }
    return p


def _split_in(cfg, h):
    d_inner, H, N, _ = _dims(cfg)
    z, xc, Bm, Cm, dt = jnp.split(
        h, np.cumsum([d_inner, d_inner, N, N]).tolist(), axis=-1)
    return z, xc, Bm, Cm, dt


def _causal_conv(xBC, w, b, cache=None):
    """Depthwise causal conv, window K.  xBC: (B, S, C).
    cache: (B, K-1, C) previous inputs (decode/chunk streaming)."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = cache.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i].astype(xBC.dtype)
              for i in range(K))
    new_cache = xp[:, -(K - 1):]
    return jax.nn.silu(out + b.astype(xBC.dtype)), new_cache


def _segsum(a):
    """a: (..., cs) -> (..., cs, cs) with T[i,j] = sum_{j<k<=i} a_k (j<=i)."""
    cs = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    T = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    return jnp.where(mask, T, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk, init_state=None):
    """SSD (Mamba2 alg. listing), chunked.

    x: (b, s, h, p)  dt: (b, s, h) (already softplus'd)  A: (h,) negative
    Bm, Cm: (b, s, n)  -> y: (b, s, h, p), final_state: (b, h, p, n)
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)

    # mixed precision (EXPERIMENTS.md §Perf M3): the decay/state math stays
    # fp32; the bulk (p-dim) tensors keep the input dtype (bf16 on TPU)
    cdt = x.dtype
    xdt = (xc * dtc[..., None].astype(cdt))        # input discretization
    Adt = (dtc * A[None, None, None, :]).astype(jnp.float32)    # (b,nc,cs,h)
    Acum = jnp.cumsum(Adt, axis=2)                 # (b,nc,cs,h)

    # intra-chunk (quadratic within chunk)
    Lmat = jnp.exp(_segsum(Adt.transpose(0, 1, 3, 2))).astype(cdt)
    scores = jnp.einsum("bzin,bzjn->bzij", Cc, Bc,
                        preferred_element_type=jnp.float32).astype(cdt)
    y_diag = jnp.einsum("bzij,bzhij,bzjhp->bzihp", scores, Lmat, xdt,
                        preferred_element_type=jnp.float32)

    # per-chunk final states
    decay_states = jnp.exp(Acum[:, :, -1:, :] - Acum).astype(cdt)
    states = jnp.einsum("bzcn,bzch,bzchp->bzhpn", Bc, decay_states, xdt,
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence — the STATE stays fp32 (official Mamba2 keeps
    # fp32 states; also the `states` einsum accumulates f32)
    chunk_decay = jnp.exp(Acum[:, :, -1, :])                    # (b,nc,h)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state *before* this chunk

    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # (b,nc,h,p,n)

    # contribution of carried-in state
    state_decay = jnp.exp(Acum)                                 # (b,nc,cs,h)
    y_off = jnp.einsum("bzcn,bzhpn,bzch->bzchp", Cc.astype(jnp.float32),
                       prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def mamba_apply(p, cfg, x, init_state=None, conv_cache=None):
    """Full-sequence Mamba2 block.  x: (B, S, d) -> (y, (state, conv_cache))."""
    d_inner, H, N, _ = _dims(cfg)
    B, S, _ = x.shape
    h = x @ p["in_proj"].astype(x.dtype)
    z, xc, Bm, Cm, dt = _split_in(cfg, h)
    xBC, new_conv = _causal_conv(jnp.concatenate([xc, Bm, Cm], -1),
                                 p["conv_w"], p["conv_b"], conv_cache)
    xc, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xc.reshape(B, S, H, cfg.ssm_head_dim)
    y, state = ssd_chunked(xh, dt, A, Bm, Cm,
                           min(cfg.ssm_chunk, S), init_state)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = L.norm_apply(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"].astype(x.dtype), (state, new_conv)


def mamba_init_cache(cfg, batch, dtype):
    d_inner, H, N, conv_dim = _dims(cfg)
    return {
        "state": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), jnp.dtype(dtype)),
    }


def mamba_decode(p, cfg, x, cache):
    """Single-token state update.  x: (B, 1, d)."""
    d_inner, H, N, _ = _dims(cfg)
    B = x.shape[0]
    h = x @ p["in_proj"].astype(x.dtype)
    z, xc, Bm, Cm, dt = _split_in(cfg, h)
    xBC, new_conv = _causal_conv(jnp.concatenate([xc, Bm, Cm], -1),
                                 p["conv_w"], p["conv_b"], cache["conv"])
    xc, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]   # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xc[:, 0].reshape(B, H, cfg.ssm_head_dim).astype(jnp.float32)
    dA = jnp.exp(dt * A[None, :])                                    # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm[:, 0].astype(jnp.float32), xh)
    state = cache["state"] * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), state)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = L.norm_apply(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"].astype(x.dtype), {"state": state, "conv": new_conv}
