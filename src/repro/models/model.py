"""Top-level models: DecoderLM (dense/moe/vlm), MambaLM (ssm), ZambaLM
(hybrid), Whisper (audio enc-dec).

Uniform functional API, driven by a typed ``ExecutionPlan``
(``core/plan.py`` — phase, TP style, sequence parallelism, mesh/axes):

    init_params(key, cfg)                     -> params
    forward(params, cfg, batch, plan)         -> (logits, aux_loss, extras)
    loss_fn(params, cfg, batch, plan)         -> (loss, metrics)
    init_cache(cfg, batch, seq, dtype)        -> decode cache pytree
    decode_step / paged_decode_step(..., plan)

``plan`` accepts an ExecutionPlan, a Phase (or its string value, e.g.
"train"), or None (single device).

Layer stacks run under ``jax.lax.scan`` over stacked params (bounded HLO for
61-layer models); blocks are ``jax.checkpoint``-ed when cfg.remat.  The FAL
first-attention signal is produced by the unscanned block 0 and closed over
by the scan body (a scan-carried constant — zero recompute, DESIGN.md §7).

Tensor parallelism: ``ExecutionPlan.from_mesh(mesh)`` (tp='gspmd') runs the
forward under implicit GSPMD sharding; ``tp='explicit'`` routes the decoder
family through ``decoder_stack_tp`` — ONE shard_map over the whole block
stack in which attention/FFN kernels see their weight shards and return
partial sums, and ``blocks.block_apply`` realises the paper's per-block
collective structure (fal/parallel: one fused all-reduce; preln/falplus:
two; block 0 pays the single extra assemble for the first-attention
export).  ``sp=True`` additionally keeps inter-block activations sharded
over the model axis along the sequence (Megatron-SP LN regions): every
per-block all-reduce becomes a reduce-scatter at 1/tp the bytes, paired
with an all-gather around the LN regions — same reduce-collective count,
and block 0 still pays the one true all-reduce that exports the
first-attention signal.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fal
from repro.core.plan import ExecutionPlan, Phase
from repro.core.plan import EXPLICIT_TP_FAMILIES  # noqa: F401 (re-export)
from repro.models import attention as A
from repro.models import blocks as BL
from repro.models import layers as L
from repro.models import ssm as S


# ------------------------------------------------------------------------- #
# helpers
# ------------------------------------------------------------------------- #
def _stack_init(key, n, init_fn):
    if n == 0:
        return None
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _layer_kind(cfg, i):
    if cfg.n_experts and i >= cfg.first_dense_layers:
        return "moe"
    return "dense"


def cross_entropy(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


# ------------------------------------------------------------------------- #
# DecoderLM: dense / moe / vlm
# ------------------------------------------------------------------------- #
def _decoder_init(key, cfg):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model,
                                               cfg.param_dtype)}
    if cfg.learned_pos:
        p["pos_emb"] = jax.random.normal(
            ks[1], (cfg.max_seq, cfg.d_model), jnp.dtype(cfg.param_dtype)) * 0.02
    p["block0"] = BL.block_init(ks[2], cfg, kind=_layer_kind(cfg, 0),
                                is_block0=True)
    n_rest = cfg.n_layers - 1
    fd = max(cfg.first_dense_layers - 1, 0) if cfg.n_experts else n_rest
    n_moe = n_rest - fd if cfg.n_experts else 0
    if fd:
        p["blocks_dense"] = _stack_init(
            ks[3], fd, lambda k: BL.block_init(k, cfg, kind="dense"))
    if n_moe:
        p["blocks_moe"] = _stack_init(
            ks[4], n_moe, lambda k: BL.block_init(k, cfg, kind="moe"))
    p["final_norm"] = L.norm_init(cfg.d_model, cfg.norm, cfg.param_dtype)
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(ks[5], cfg.d_model, cfg.vocab, cfg.param_dtype)
    if cfg.mtp_depth:
        p["mtp"] = {
            "proj": L.dense_init(ks[6], 2 * cfg.d_model, cfg.d_model,
                                 cfg.param_dtype),
            "norm_h": L.norm_init(cfg.d_model, cfg.norm, cfg.param_dtype),
            "norm_e": L.norm_init(cfg.d_model, cfg.norm, cfg.param_dtype),
            # the MTP head block is a plain preln block (it sits outside the
            # main depth, so FAL's first-attention rewiring does not apply)
            "block": BL.block_init(ks[7], cfg.replace(connection="preln"),
                                   kind="dense"),
        }
    return p


def constrain_batch(x, plan: Optional[ExecutionPlan]):
    """Pin activations to batch-over-data sharding (GSPMD anchor after the
    vocab-sharded embedding gather)."""
    if plan is None or plan.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = P(tuple(plan.data_axes) or None, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(plan.mesh, spec))


def _embed_tokens(p, cfg, tokens, positions, image_embeds=None):
    x = L.embed_apply(p["embed"], tokens, cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.learned_pos:
        x = x + p["pos_emb"].astype(x.dtype)[positions]
    if image_embeds is not None and cfg.n_image_tokens:
        n = cfg.n_image_tokens
        x = jnp.concatenate([image_embeds.astype(x.dtype), x[:, n:]], axis=1)
    return x


def _logits(p, cfg, x):
    x = L.norm_apply(p["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        return L.unembed_apply(p["embed"], x, cfg.final_softcap)
    return L.softcap(L.dense_apply(p["head"], x), cfg.final_softcap)


def decoder_stack_tp(p, cfg, x, positions, plan: ExecutionPlan):
    """Block 0 + the scanned segments under ONE shard_map with explicit
    Megatron-style partial sums — the paper's Fig 2 on the real model.

    Weights enter through ``launch.mesh.param_specs`` (attention heads + FFN
    hidden column/row over the model axis, MoE experts over the model axis);
    activations are sharded over the data axes and — replicated over
    ``model`` by default, or sharded over ``model`` along the SEQUENCE when
    ``plan.sequence_parallel`` (Megatron-SP: the residual stream between
    blocks is (B, S/tp, D) per device).  Inside, blocks see ``plan.inner()``
    (``plan.tp_axis`` set) and compose the partial sums per
    ``core.fal.attention_must_assemble`` — fal/parallel pay one reduce
    collective per steady-state block, preln/falplus two, and the unscanned
    block 0 pays the one extra assemble that exports the first-attention
    signal; under SP each all-reduce becomes a reduce-scatter at 1/tp the
    bytes behind an all-gather of the LN region.  Returns (x, aux)."""
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import shard_map
    from repro.launch import mesh as MX

    plan.validate(cfg)
    mesh = plan.mesh
    dax = tuple(plan.data_axes)
    max_ = plan.model_axis
    tp_size = plan.tp_size
    sp = plan.sequence_parallel
    if sp and x.shape[1] % tp_size:
        raise ValueError(
            f"sequence_parallel: seq len {x.shape[1]} is not divisible by "
            f"tp_size={tp_size} (the residual stream shards evenly or not "
            f"at all)")
    blocks = {k: p[k] for k in ("block0", "blocks_dense", "blocks_moe")
              if p.get(k) is not None}
    kv_rep = (not cfg.use_mla) and cfg.n_kv_heads % tp_size != 0
    wspecs = MX.param_specs(blocks, cfg,
                            kv_replicated=kv_rep)  # Megatron, model axis only
    inner = plan.inner()
    b_ax = dax if dax else None
    s_ax = max_ if sp else None

    def local(bp, x, positions):
        x, aux = _run_decoder_blocks(bp, cfg, x, positions, inner)
        if dax:
            # MoE aux differs per data shard (local routing); make it the
            # global mean so the out_spec can declare it replicated
            aux = jax.lax.pmean(aux, dax)
        return x, aux

    fn = shard_map(local, mesh=mesh,
                   in_specs=(wspecs, P(b_ax, s_ax, None), P(b_ax, None)),
                   out_specs=(P(b_ax, s_ax, None), P()),
                   check_vma=False)
    return fn(blocks, x, positions)


def _run_decoder_blocks(p, cfg, x, positions, plan: ExecutionPlan):
    """Block 0 + the scanned dense/moe segments.  ONE implementation shared
    by the replicated/GSPMD path and the explicit-TP shard_map local body —
    the collective structure differs only through the plan the blocks see.
    Returns (x, aux).

    Block 0 sits outside the layer scan; without its own remat its
    attention residuals (probs etc.) are stashed for backward
    (EXPERIMENTS.md §Perf D2)."""
    wsched = BL.window_schedule(cfg)
    block0 = _maybe_remat(
        lambda pb, h: BL.block_apply(pb, cfg, h, None, positions, wsched[0],
                                     kind=_layer_kind(cfg, 0), is_block0=True,
                                     plan=plan),
        cfg)
    x, a1_raw, aux, _ = block0(p["block0"], x)
    a1_sig = fal.first_attention_signal(cfg, p["block0"], a1_raw)

    i = 1
    for name, kind in (("blocks_dense", "dense"), ("blocks_moe", "moe")):
        if p.get(name) is not None:
            n = jax.tree.leaves(p[name])[0].shape[0]
            ws = jnp.asarray(wsched[i:i + n], jnp.int32)
            x, aux_s = _run_stack(p[name], cfg, x, a1_sig, positions, ws,
                                  kind, plan)
            aux += aux_s
            i += n
    return x, aux


def _run_stack(p_stack, cfg, x, a1_sig, positions, windows, kind,
               plan: ExecutionPlan):
    """Scan blocks over stacked params.  Returns (x, aux_sum)."""
    def body(carry, xs):
        h, aux = carry
        pb, w = xs
        h, _, aux_i, _ = BL.block_apply(
            pb, cfg, h, a1_sig, positions, w, kind=kind, plan=plan)
        return (h, aux + aux_i), None

    body = _maybe_remat(body, cfg)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (p_stack, windows))
    return x, aux


def _decoder_forward(p, cfg, batch, plan: ExecutionPlan, want="logits"):
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    x = _embed_tokens(p, cfg, tokens, positions,
                      batch.get("image_embeds"))
    x = constrain_batch(x, plan)

    if plan.use_explicit_tp:
        x, aux = decoder_stack_tp(p, cfg, x, positions, plan)
    else:
        x, aux = _run_decoder_blocks(p, cfg, x, positions, plan)

    if want == "hidden":
        return None, aux, {"hidden": x}
    logits = _logits(p, cfg, x)
    extras = {"hidden": x} if cfg.mtp_depth else {}
    return logits, aux, extras


def _decoder_init_cache(p, cfg, batch, seq, dtype):
    B = batch
    mk = (A.mla_init_cache if cfg.use_mla else A.gqa_init_cache)
    c0 = mk(cfg, B, seq, dtype)
    rest = cfg.n_layers - 1
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (rest,) + a.shape), c0)
    return {"block0": c0, "blocks": stacked}


def _decoder_layer_stack(p, cfg, x, a1_sig, pos, blocks_cache,
                         plan: ExecutionPlan, block_tables=None,
                         n_valid=None, tok_slot=None, tok_pos=None,
                         limit=None):
    """Scan the stacked post-block0 layers in dense/moe segments over
    per-layer caches (dense+moe kinds share attention caches; the ffn kind
    switch is static per segment).  Returns (x, new_stacked_cache).

    When every layer's window is statically 0 (no sliding windows) the
    window rides into the scan body as a Python int instead of a traced
    vector — attention's static ``window == 0`` checks then hold, keeping
    the paged single-token fast path (kernels.ops.paged_decode_attention)
    live for the stacked layers, not just block 0.

    ``limit`` (static) runs only the FIRST ``limit`` stacked layers in
    depth order across the dense/moe segments — the speculative-decode
    draft's early exit.  The returned cache then stacks only those
    ``limit`` layers (None when limit == 0); the caller merges it back
    over the untouched upper slice."""
    wsched = BL.window_schedule(cfg)[1:]
    static_zero = all(isinstance(w, int) and w == 0 for w in wsched)
    ws_all = jnp.asarray(wsched, jnp.int32)
    remaining = cfg.n_layers - 1 if limit is None else limit
    i = 0
    seg_caches = []
    for name, kind in (("blocks_dense", "dense"), ("blocks_moe", "moe")):
        if remaining > 0 and name in p and p[name] is not None:
            n = jax.tree.leaves(p[name])[0].shape[0]
            n = min(n, remaining)
            remaining -= n
            pseg = p[name] if limit is None else \
                jax.tree.map(lambda a: a[:n], p[name])
            ws = None if static_zero else jax.lax.slice_in_dim(ws_all, i, i + n)
            cache_seg = jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, i, i + n), blocks_cache)

            def body(h, xs, kind=kind):
                if static_zero:
                    (pb, ci), w = xs, 0
                else:
                    pb, w, ci = xs
                h, _, _, c_new = BL.block_apply(
                    pb, cfg, h, a1_sig, None, w, kind=kind, plan=plan,
                    cache=ci, pos=pos, block_tables=block_tables,
                    n_valid=n_valid, tok_slot=tok_slot, tok_pos=tok_pos)
                return h, c_new

            xs = (pseg, cache_seg) if static_zero else \
                (pseg, ws, cache_seg)
            x, cseg = jax.lax.scan(body, x, xs)
            seg_caches.append(cseg)
            i += n
    if not seg_caches:                         # limit == 0: block 0 only
        return x, None
    return x, jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *seg_caches)


def _decoder_decode(p, cfg, batch, cache, plan: ExecutionPlan):
    tokens, pos = batch["tokens"], batch["pos"]
    positions = pos[:, None]
    x = _embed_tokens(p, cfg, tokens, positions)
    if cfg.n_image_tokens and "image_embeds" in batch:
        # VLM: while decoding through the image prefix the serving engine
        # passes the precomputed patch embedding for the current position
        x = jnp.where((pos < cfg.n_image_tokens)[:, None, None],
                      batch["image_embeds"].astype(x.dtype), x)
    wsched = BL.window_schedule(cfg)

    x, a1_raw, _, c0 = BL.block_apply(
        p["block0"], cfg, x, None, positions, wsched[0],
        kind=_layer_kind(cfg, 0), is_block0=True, plan=plan,
        cache=cache["block0"], pos=pos)
    a1_sig = fal.first_attention_signal(cfg, p["block0"], a1_raw)

    x, blocks_new = _decoder_layer_stack(p, cfg, x, a1_sig, pos,
                                         cache["blocks"], plan)
    logits = _logits(p, cfg, x)
    return logits, {"block0": c0, "blocks": blocks_new}


# ------------------------------------------------------------------------- #
# paged decode (serving engine): block-table KV cache, chunked ticks
# ------------------------------------------------------------------------- #
def _decoder_init_paged_cache(cfg, num_pages, page_size, slots, dtype,
                              kv_dtype=""):
    if cfg.use_mla:
        if kv_dtype:
            raise NotImplementedError(
                "quantized KV pages (kv_dtype) are GQA-only: the MLA cache "
                "stores latents, not per-head K/V rows")
        c0 = A.mla_init_paged_cache(cfg, num_pages, page_size, dtype)
    else:
        c0 = A.gqa_init_paged_cache(cfg, num_pages, page_size, dtype,
                                    kv_dtype=kv_dtype)
    rest = cfg.n_layers - 1
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (rest,) + a.shape), c0)
    return {
        "block0": c0, "blocks": stacked,
        # per-slot FAL export: block 1's first-attention signal at the last
        # position this slot processed.  Written every paged tick so engine
        # consumers (telemetry, and the dual-branch MHA||MLP decode dispatch
        # under plan.dual_branch) read the cached tensor instead of
        # re-running block 1's export.
        "a1_sig": jnp.zeros((slots, cfg.d_model), jnp.dtype(dtype)),
    }


def _decoder_paged_decode(p, cfg, batch, cache, plan: ExecutionPlan,
                          want="logits"):
    """Chunked paged tick: C >= 1 tokens per request against page pools.

    batch: tokens (B, C), pos (B,) PER-LANE first logical position, n_valid
    (B,) valid tokens per lane (invalid lanes -> scratch page),
    block_tables (B, T).  Returns (logits (B, C, V), new_cache).  Lanes are
    phase-independent: a C > 1 tick serves any mix of prefilling lanes
    (n_valid up to C) and decoding lanes (n_valid == 1); the serving
    engine now compiles the token-PACKED program instead (flat batch
    with ``tok_slot``/``tok_pos``); C == 1 is the retired decode-only
    tick shape.  Full attention runs the block-table
    kernels (``kernels.ops.paged_chunk_attention`` for C > 1,
    ``paged_decode_attention`` for C == 1) with no gathered HBM copy.

    With ``plan.dual_branch`` (fal/parallel-family connections only,
    ``plan.validate``) the steady-state blocks run the MHA||MLP
    branch-parallel dispatch: the MLP branch reads the cached per-slot
    first-attention signal (``cache['a1_sig']``, refreshed by block 0 at
    the top of the tick) concurrently with the attention branch's paged KV
    gather — logits are bit-identical to the sequential path whenever both
    run the same dispatch (always on the CPU fallback; the fused TPU kernel
    is tolerance-close to the unfused ops).
    """
    tokens, pos = batch["tokens"], batch["pos"]
    bt, n_valid = batch["block_tables"], batch["n_valid"]
    B, C = tokens.shape
    positions = pos[:, None] + jnp.arange(C)[None]
    x = _embed_tokens(p, cfg, tokens, positions)
    if cfg.n_image_tokens and "image_embeds" in batch:
        # VLM: patch embeddings for the chunk lanes inside the image prefix
        # (same contract as _decoder_decode, lane-wise over the chunk)
        x = jnp.where((positions < cfg.n_image_tokens)[:, :, None],
                      batch["image_embeds"].astype(x.dtype), x)
    x = constrain_batch(x, plan)
    wsched = BL.window_schedule(cfg)

    x, a1_raw, _, c0 = BL.block_apply(
        p["block0"], cfg, x, None, positions, wsched[0],
        kind=_layer_kind(cfg, 0), is_block0=True, plan=plan,
        cache=cache["block0"], pos=pos, block_tables=bt, n_valid=n_valid)
    a1_sig = fal.first_attention_signal(cfg, p["block0"], a1_raw)
    new_caches = {"block0": c0}

    # stash the per-request FAL export at each request's last valid position
    # BEFORE the steady-state stack runs; slots sitting this call out
    # (n_valid == 0) keep their cached signal
    sig = a1_sig if a1_sig is not None else a1_raw
    last = jnp.clip(n_valid - 1, 0, C - 1)
    new_sig = jnp.take_along_axis(
        sig, last[:, None, None], axis=1)[:, 0].astype(cache["a1_sig"].dtype)
    new_caches["a1_sig"] = jnp.where((n_valid > 0)[:, None], new_sig,
                                     cache["a1_sig"])

    if plan.dual_branch and a1_sig is not None and C == 1:
        # dual-branch decode tick: active lanes keep this tick's FRESH
        # activation-dtype export (bit-identical to the sequential path for
        # ANY cache dtype — routing it through the cache would round it);
        # lanes sitting the tick out read their held per-slot cached signal
        # instead of a padded lane's garbage position
        a1_sig = jnp.where((n_valid > 0)[:, None], sig[:, 0],
                           cache["a1_sig"].astype(x.dtype))[:, None, :]

    x, blocks_new = _decoder_layer_stack(p, cfg, x, a1_sig, pos,
                                         cache["blocks"], plan,
                                         block_tables=bt, n_valid=n_valid)
    new_caches["blocks"] = blocks_new

    if want == "hidden":
        # serving engines consume ONE row of logits per lane (the last
        # valid one): skip the (B, C, V) head here and let the caller run
        # ``lm_head`` on the gathered lane — at C == prefill_chunk that is
        # 1/C of the tick's dominant matmul
        return x, new_caches
    logits = _logits(p, cfg, x)
    return logits, new_caches


def _decoder_paged_packed(p, cfg, batch, cache, plan: ExecutionPlan,
                          want="logits"):
    """Token-PACKED ragged tick: one flat (T,) token buffer against page
    pools — the serving engine's ONE program per tick.

    batch: tokens (T,), tok_slot (T,) owning lane per token, tok_pos (T,)
    logical position per token (-1 = padding: scatters to scratch, emits
    meaningless rows), block_tables (S, Tb) per-SLOT tables, seg_last (S,)
    index of each slot's LAST packed token in the buffer (-1 = slot sat
    this tick out).  Returns (logits (1, T, V) — or hidden (1, T, D) with
    ``want='hidden'`` — and new_cache).

    A prefilling lane contributes up to ``chunk`` contiguous tokens and a
    decoding lane exactly one, so the tick's FLOPs scale with LIVE tokens
    instead of slots x chunk (the padded `_decoder_paged_decode` layout).
    The per-slot FAL export (``cache['a1_sig']``) is refreshed from each
    active slot's seg_last row; with ``plan.dual_branch`` the steady-state
    blocks run MHA||MLP off this tick's fresh per-token signal — every
    packed token is a live token at its own position, so no per-slot
    substitution is needed and tokens stay bit-identical to the sequential
    packed path.
    """
    tokens, bt = batch["tokens"], batch["block_tables"]
    tok_slot, tok_pos = batch["tok_slot"], batch["tok_pos"]
    seg_last = batch["seg_last"]
    positions = jnp.maximum(tok_pos, 0)[None]                   # (1, T)
    x = _embed_tokens(p, cfg, tokens[None], positions)
    if cfg.n_image_tokens and "image_embeds" in batch:
        # VLM: per-token patch embeddings for packed tokens inside the
        # image prefix (batch["image_embeds"]: (T, D))
        x = jnp.where((positions < cfg.n_image_tokens)[:, :, None],
                      batch["image_embeds"][None].astype(x.dtype), x)
    x = constrain_batch(x, plan)
    wsched = BL.window_schedule(cfg)

    x, a1_raw, _, c0 = BL.block_apply(
        p["block0"], cfg, x, None, positions, wsched[0],
        kind=_layer_kind(cfg, 0), is_block0=True, plan=plan,
        cache=cache["block0"], block_tables=bt,
        tok_slot=tok_slot, tok_pos=tok_pos)
    a1_sig = fal.first_attention_signal(cfg, p["block0"], a1_raw)
    new_caches = {"block0": c0}

    # refresh the per-slot FAL export from each active segment's LAST
    # packed token; slots sitting this tick out keep their cached signal
    sig = a1_sig if a1_sig is not None else a1_raw              # (1, T, D)
    active = seg_last >= 0
    new_sig = sig[0, jnp.maximum(seg_last, 0)].astype(cache["a1_sig"].dtype)
    new_caches["a1_sig"] = jnp.where(active[:, None], new_sig,
                                     cache["a1_sig"])

    x, blocks_new = _decoder_layer_stack(p, cfg, x, a1_sig, None,
                                         cache["blocks"], plan,
                                         block_tables=bt,
                                         tok_slot=tok_slot, tok_pos=tok_pos)
    new_caches["blocks"] = blocks_new

    if want == "hidden":
        # the engine reads ONE row per segment (seg_last): skip the
        # (1, T, V) head here and let the caller run ``lm_head`` on the
        # gathered segment-last rows
        return x, new_caches
    logits = _logits(p, cfg, x)
    return logits, new_caches


def _decoder_paged_packed_draft(p, cfg, batch, cache, plan: ExecutionPlan,
                                draft_blocks):
    """Early-exit packed forward for the self-speculative DRAFT path: run
    block 0 plus the first ``draft_blocks - 1`` stacked layers (depth order
    across the dense/moe segments) over the packed batch and return the
    truncated-stack hidden states — FAL's defining property (every later
    MLP reads block 0's first-attention signal, not its neighbour's
    attention) makes this shallow prefix unusually self-contained, so
    ``lm_head`` over it is the engine's draft model at ~draft_blocks /
    n_layers of the FLOPs and zero extra weights.

    Returns (hidden (1, T, D), new_cache).  K/V is scattered for the draft
    layers only — the verify pass recomputes layers < draft_blocks on the
    same tokens and overwrites those rows with identical values (the
    activations agree layer-for-layer), and is the first writer for every
    deeper layer.  ``cache['a1_sig']`` is NOT refreshed here: the per-slot
    export must track the lane's last ACCEPTED position, which only the
    verify pass knows.  Kernel dispatches traced inside carry a
    ``.draft`` site suffix so runtime telemetry separates the draft's
    attention path from the verify's."""
    from repro.kernels import ops as _ops
    tokens, bt = batch["tokens"], batch["block_tables"]
    tok_slot, tok_pos = batch["tok_slot"], batch["tok_pos"]
    positions = jnp.maximum(tok_pos, 0)[None]                   # (1, T)
    with _ops.dispatch_site_suffix("draft"):
        x = _embed_tokens(p, cfg, tokens[None], positions)
        x = constrain_batch(x, plan)
        wsched = BL.window_schedule(cfg)
        x, a1_raw, _, c0 = BL.block_apply(
            p["block0"], cfg, x, None, positions, wsched[0],
            kind=_layer_kind(cfg, 0), is_block0=True, plan=plan,
            cache=cache["block0"], block_tables=bt,
            tok_slot=tok_slot, tok_pos=tok_pos)
        a1_sig = fal.first_attention_signal(cfg, p["block0"], a1_raw)
        new_caches = {"block0": c0, "a1_sig": cache["a1_sig"]}
        x, low = _decoder_layer_stack(p, cfg, x, a1_sig, None,
                                      cache["blocks"], plan,
                                      block_tables=bt, tok_slot=tok_slot,
                                      tok_pos=tok_pos,
                                      limit=draft_blocks - 1)
    if low is None:
        new_caches["blocks"] = cache["blocks"]
    else:
        upper = jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, draft_blocks - 1, a.shape[0]),
            cache["blocks"])
        new_caches["blocks"] = jax.tree.map(
            lambda lo, hi: jnp.concatenate([lo, hi], 0), low, upper)
    return x, new_caches


def _mamba_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln": L.norm_init(cfg.d_model, cfg.norm, cfg.param_dtype),
            "mixer": S.mamba_init(k2, cfg)}


def _mamba_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "blocks": _stack_init(ks[1], cfg.n_layers,
                              lambda k: _mamba_block_init(k, cfg)),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm, cfg.param_dtype),
    }


def _mamba_forward(p, cfg, batch, plan: ExecutionPlan, want="logits"):
    x = L.embed_apply(p["embed"], batch["tokens"], cfg.dtype)
    x = constrain_batch(x, plan)

    def body(h, pb):
        # pin the mixer input/output to batch-over-data sharding: without
        # the anchor GSPMD auto-spreads the SSD einsums over the idle
        # `model` axis and pays reshard collectives every layer
        # (EXPERIMENTS.md §Perf M1)
        h_in = constrain_batch(L.norm_apply(pb["ln"], h, cfg.norm), plan)
        y, _ = S.mamba_apply(pb["mixer"], cfg, h_in)
        y = constrain_batch(y, plan)
        return h + y, None

    body = _maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, p["blocks"])
    if want == "hidden":
        return None, jnp.zeros((), jnp.float32), {"hidden": x}
    return _logits(p, cfg, x), jnp.zeros((), jnp.float32), {}


def _mamba_init_cache(cfg, batch, seq, dtype):
    c0 = S.mamba_init_cache(cfg, batch, dtype)
    return {"blocks": jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), c0)}


def _mamba_decode(p, cfg, batch, cache, plan: ExecutionPlan = None):
    x = L.embed_apply(p["embed"], batch["tokens"], cfg.dtype)

    def body(h, xs):
        pb, ci = xs
        y, c_new = S.mamba_decode(pb["mixer"], cfg,
                                  L.norm_apply(pb["ln"], h, cfg.norm), ci)
        return h + y, c_new

    x, new_c = jax.lax.scan(body, x, (p["blocks"], cache["blocks"]))
    return _logits(p, cfg, x), {"blocks": new_c}


# ------------------------------------------------------------------------- #
# ZambaLM (hybrid): mamba2 backbone + weight-shared attention block
# ------------------------------------------------------------------------- #
def _zamba_counts(cfg):
    n_groups = cfg.n_layers // cfg.attn_every
    trailing = cfg.n_layers - n_groups * cfg.attn_every
    return n_groups, trailing


def _zamba_init(key, cfg):
    ks = jax.random.split(key, 8)
    n_groups, trailing = _zamba_counts(cfg)
    d = cfg.d_model
    p = {
        "embed": L.embed_init(ks[0], cfg.vocab, d, cfg.param_dtype),
        # stacked (n_groups, attn_every, ...) mamba blocks
        "mamba": _stack_init(
            ks[1], n_groups,
            lambda k: _stack_init(k, cfg.attn_every,
                                  lambda k2: _mamba_block_init(k2, cfg))),
        # ONE weight-shared transformer block (zamba2); per-invocation input
        # projections concat([x, x_emb0]) -> d give invocation specificity
        "shared": BL.block_init(ks[2], cfg, kind="dense", is_block0=True),
        "in_proj": jax.random.normal(
            ks[3], (n_groups, 2 * d, d), jnp.dtype(cfg.param_dtype)) / np.sqrt(2 * d),
        "final_norm": L.norm_init(d, cfg.norm, cfg.param_dtype),
    }
    if cfg.connection in fal.NEEDS_LN_FAL:
        p["shared_ln_fal"] = L.norm_init(d, cfg.norm, cfg.param_dtype)
    if trailing:
        p["mamba_tail"] = _stack_init(
            ks[4], trailing, lambda k: _mamba_block_init(k, cfg))
    return p


def _zamba_shared_block(p, cfg, x, x0, in_proj, a1_sig, positions, *,
                        first, plan=None, cache=None, pos=None):
    """One invocation of the weight-shared attention block (FAL-aware)."""
    h_in = jnp.concatenate([x, x0], axis=-1) @ in_proj.astype(x.dtype)
    shared = dict(p["shared"])
    if "shared_ln_fal" in p:
        shared["ln_fal"] = p["shared_ln_fal"]
    out, a_raw, _, c_new = BL.block_apply(
        shared, cfg, h_in, a1_sig, positions, 0, kind="dense",
        is_block0=first, plan=plan, cache=cache, pos=pos)
    # block returns h_in + attn + mlp; zamba adds only the delta to the
    # backbone residual stream
    return x + (out - h_in), a_raw, c_new


def _zamba_forward(p, cfg, batch, plan: ExecutionPlan, want="logits"):
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    x0 = L.embed_apply(p["embed"], tokens, cfg.dtype)
    x = x0
    n_groups, trailing = _zamba_counts(cfg)

    def mamba_seg(h, pstack):
        def body(hh, pb):
            # same activation pin as MambaLM (EXPERIMENTS.md §Perf M1)
            h_in = constrain_batch(L.norm_apply(pb["ln"], hh, cfg.norm),
                                   plan)
            y, _ = S.mamba_apply(pb["mixer"], cfg, h_in)
            return hh + constrain_batch(y, plan), None
        h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, pstack)
        return h

    # group 0 (produces the first-attention signal); rematted — it sits
    # outside the group scan (EXPERIMENTS.md §Perf D2)
    def group0(p, x):
        x = mamba_seg(x, jax.tree.map(lambda a: a[0], p["mamba"]))
        return _zamba_shared_block(
            p, cfg, x, x0, p["in_proj"][0], None, positions, first=True,
            plan=plan)
    x, a1_raw, _ = _maybe_remat(group0, cfg)(p, x)
    a1_sig = fal.first_attention_signal(cfg, p["shared"], a1_raw)

    def group_body(h, xs):
        pst, iproj = xs
        h = mamba_seg(h, pst)
        h, _, _ = _zamba_shared_block(p, cfg, h, x0, iproj, a1_sig,
                                      positions, first=False, plan=plan)
        return h, None

    if n_groups > 1:
        rest = jax.tree.map(lambda a: a[1:], p["mamba"])
        x, _ = jax.lax.scan(_maybe_remat(group_body, cfg), x,
                            (rest, p["in_proj"][1:]))
    if trailing:
        x = mamba_seg(x, p["mamba_tail"])
    if want == "hidden":
        return None, jnp.zeros((), jnp.float32), {"hidden": x}
    return _logits(p, cfg, x), jnp.zeros((), jnp.float32), {}


def _zamba_init_cache(cfg, batch, seq, dtype):
    n_groups, trailing = _zamba_counts(cfg)
    mc = S.mamba_init_cache(cfg, batch, dtype)
    ac = A.gqa_init_cache(cfg, batch, seq, dtype)
    st = lambda c, n: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c)
    cache = {"mamba": st(st(mc, cfg.attn_every), n_groups),
             "attn": st(ac, n_groups)}
    if trailing:
        cache["mamba_tail"] = st(mc, trailing)
    return cache


def _zamba_decode(p, cfg, batch, cache, plan: ExecutionPlan):
    tokens, pos = batch["tokens"], batch["pos"]
    x0 = L.embed_apply(p["embed"], tokens, cfg.dtype)
    x = x0
    n_groups, trailing = _zamba_counts(cfg)

    def mamba_seg(h, pstack, cstack):
        def body(hh, xs):
            pb, ci = xs
            y, c_new = S.mamba_decode(pb["mixer"], cfg,
                                      L.norm_apply(pb["ln"], hh, cfg.norm), ci)
            return hh + y, c_new
        return jax.lax.scan(body, h, (pstack, cstack))

    x, mc0 = mamba_seg(x, jax.tree.map(lambda a: a[0], p["mamba"]),
                       jax.tree.map(lambda a: a[0], cache["mamba"]))
    x, a1_raw, ac0 = _zamba_shared_block(
        p, cfg, x, x0, p["in_proj"][0], None, None, first=True,
        plan=plan, cache=jax.tree.map(lambda a: a[0], cache["attn"]),
        pos=pos)
    a1_sig = fal.first_attention_signal(cfg, p["shared"], a1_raw)

    def group_body(h, xs):
        pst, iproj, mci, aci = xs
        h, mc_new = mamba_seg(h, pst, mci)
        h, _, ac_new = _zamba_shared_block(
            p, cfg, h, x0, iproj, a1_sig, None, first=False, plan=plan,
            cache=aci, pos=pos)
        return h, (mc_new, ac_new)

    new_cache = dict(cache)
    if n_groups > 1:
        rest_p = jax.tree.map(lambda a: a[1:], p["mamba"])
        rest_mc = jax.tree.map(lambda a: a[1:], cache["mamba"])
        rest_ac = jax.tree.map(lambda a: a[1:], cache["attn"])
        x, (mc_rest, ac_rest) = jax.lax.scan(
            group_body, x, (rest_p, p["in_proj"][1:], rest_mc, rest_ac))
        new_cache["mamba"] = jax.tree.map(
            lambda a, b: jnp.concatenate([a[None], b], 0), mc0, mc_rest)
        new_cache["attn"] = jax.tree.map(
            lambda a, b: jnp.concatenate([a[None], b], 0), ac0, ac_rest)
    else:
        new_cache["mamba"] = jax.tree.map(lambda a, n: a.at[0].set(n),
                                          cache["mamba"], mc0)
        new_cache["attn"] = jax.tree.map(lambda a, n: a.at[0].set(n),
                                         cache["attn"], ac0)
    if trailing:
        x, mct = mamba_seg(x, p["mamba_tail"], cache["mamba_tail"])
        new_cache["mamba_tail"] = mct
    return _logits(p, cfg, x), new_cache


# ------------------------------------------------------------------------- #
# Whisper (audio enc-dec): conv/mel frontend is a STUB — inputs are
# precomputed frame embeddings (DESIGN.md carve-out)
# ------------------------------------------------------------------------- #
def _whisper_init(key, cfg):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {
        "embed": L.embed_init(ks[0], cfg.vocab, d, cfg.param_dtype),
        "pos_emb": jax.random.normal(ks[1], (cfg.max_seq, d),
                                     jnp.dtype(cfg.param_dtype)) * 0.02,
        "enc_pos": jax.random.normal(ks[2], (cfg.n_enc_frames, d),
                                     jnp.dtype(cfg.param_dtype)) * 0.02,
        "enc_block0": BL.block_init(ks[3], cfg, is_block0=True),
        "enc_blocks": _stack_init(ks[4], cfg.n_enc_layers - 1,
                                  lambda k: BL.block_init(k, cfg)),
        "enc_norm": L.norm_init(d, cfg.norm, cfg.param_dtype),
        "dec_block0": BL.block_init(ks[5], cfg, cross=True, is_block0=True),
        "dec_blocks": _stack_init(ks[6], cfg.n_layers - 1,
                                  lambda k: BL.block_init(k, cfg, cross=True)),
        "final_norm": L.norm_init(d, cfg.norm, cfg.param_dtype),
    }
    return p


def whisper_encode(p, cfg, frames, plan: ExecutionPlan = None):
    """frames: (B, F, d) stubbed frame embeddings."""
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.PREFILL)
    x = frames.astype(jnp.dtype(cfg.dtype)) + p["enc_pos"].astype(
        jnp.dtype(cfg.dtype))[None, :frames.shape[1]]
    # encoder self-attention is bidirectional (causal=False), no rope
    enc0 = _maybe_remat(
        lambda pb, h: BL.block_apply(pb, cfg, h, None, None, 0,
                                     is_block0=True, plan=plan,
                                     causal=False), cfg)
    x, a1_raw, _, _ = enc0(p["enc_block0"], x)
    a1_sig = fal.first_attention_signal(cfg, p["enc_block0"], a1_raw)

    def body(h, pb):
        h, _, _, _ = BL.block_apply(pb, cfg, h, a1_sig, None, 0,
                                    plan=plan, causal=False)
        return h, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, p["enc_blocks"])
    return L.norm_apply(p["enc_norm"], x, cfg.norm)


def _whisper_forward(p, cfg, batch, plan: ExecutionPlan, want="logits"):
    enc_out = whisper_encode(p, cfg, batch["frames"], plan)
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    x = L.embed_apply(p["embed"], tokens, cfg.dtype) \
        + p["pos_emb"].astype(jnp.dtype(cfg.dtype))[None, :Sq]

    dec0 = _maybe_remat(
        lambda pb, h: BL.block_apply(pb, cfg, h, None, positions, 0,
                                     is_block0=True, plan=plan,
                                     enc_out=enc_out), cfg)
    x, a1_raw, _, _ = dec0(p["dec_block0"], x)
    a1_sig = fal.first_attention_signal(cfg, p["dec_block0"], a1_raw)

    def body(h, pb):
        h, _, _, _ = BL.block_apply(pb, cfg, h, a1_sig, positions, 0,
                                    plan=plan, enc_out=enc_out)
        return h, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, p["dec_blocks"])
    if want == "hidden":
        return None, jnp.zeros((), jnp.float32), {"hidden": x}
    return _logits(p, cfg, x), jnp.zeros((), jnp.float32), {}


def _whisper_init_cache(cfg, batch, seq, dtype):
    c0 = A.gqa_init_cache(cfg, batch, seq, dtype)
    rest = cfg.n_layers - 1
    return {
        "block0": c0,
        "blocks": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (rest,) + a.shape), c0),
        "enc_out": jnp.zeros((batch, cfg.n_enc_frames, cfg.d_model),
                             jnp.dtype(dtype)),
    }


def _whisper_decode(p, cfg, batch, cache, plan: ExecutionPlan):
    tokens, pos = batch["tokens"], batch["pos"]
    enc_out = cache["enc_out"].astype(jnp.dtype(cfg.dtype))
    x = L.embed_apply(p["embed"], tokens, cfg.dtype) \
        + p["pos_emb"].astype(jnp.dtype(cfg.dtype))[pos][:, None]

    x, a1_raw, _, c0 = BL.block_apply(
        p["dec_block0"], cfg, x, None, None, 0, is_block0=True,
        plan=plan, enc_out=enc_out, cache=cache["block0"], pos=pos)
    a1_sig = fal.first_attention_signal(cfg, p["dec_block0"], a1_raw)

    def body(h, xs):
        pb, ci = xs
        h, _, _, c_new = BL.block_apply(pb, cfg, h, a1_sig, None, 0,
                                        plan=plan, enc_out=enc_out,
                                        cache=ci, pos=pos)
        return h, c_new

    x, new_c = jax.lax.scan(body, x, (p["dec_blocks"], cache["blocks"]))
    return _logits(p, cfg, x), {"block0": c0, "blocks": new_c,
                                "enc_out": cache["enc_out"]}


# ------------------------------------------------------------------------- #
# dispatch
# ------------------------------------------------------------------------- #
def init_params(key, cfg):
    if cfg.family == "ssm":
        return _mamba_init(key, cfg)
    if cfg.family == "hybrid":
        return _zamba_init(key, cfg)
    if cfg.family == "audio":
        return _whisper_init(key, cfg)
    return _decoder_init(key, cfg)


def forward(params, cfg, batch, plan=None, want="logits"):
    """Full-sequence forward -> (logits, aux_loss, extras).

    ``plan``: ExecutionPlan | Phase | phase string ("train"/"prefill") |
    None (single device, train)."""
    plan = ExecutionPlan.resolve(plan).validate(cfg)
    if not plan.full_sequence:
        raise ValueError(f"forward: phase={plan.phase.value} is not a "
                         f"full-sequence phase; use decode_step / "
                         f"paged_decode_step")
    fn = {"ssm": _mamba_forward, "hybrid": _zamba_forward,
          "audio": _whisper_forward}.get(cfg.family, _decoder_forward)
    return fn(params, cfg, batch, plan, want=want)


def init_cache(cfg, batch, seq, dtype="bfloat16"):
    if cfg.family == "ssm":
        return _mamba_init_cache(cfg, batch, seq, dtype)
    if cfg.family == "hybrid":
        return _zamba_init_cache(cfg, batch, seq, dtype)
    if cfg.family == "audio":
        return _whisper_init_cache(cfg, batch, seq, dtype)
    return _decoder_init_cache(None, cfg, batch, seq, dtype)


def decode_step(params, cfg, batch, cache, plan=None):
    """-> (logits (B,1,V), new_cache)."""
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.DECODE).validate(cfg)
    fn = {"ssm": _mamba_decode, "hybrid": _zamba_decode,
          "audio": _whisper_decode}.get(cfg.family, _decoder_decode)
    return fn(params, cfg, batch, cache, plan)


PAGED_FAMILIES = ("dense", "moe", "vlm")


def init_paged_cache(cfg, num_pages, page_size, slots, dtype="bfloat16",
                     kv_dtype=""):
    """Paged-KV cache for the decoder family: (num_pages, page_size, ...)
    pools per layer + a per-slot FAL-signal buffer.  Page 0 is scratch
    (see attention.paged_scatter).  Slots are phase-independent — each
    lane's position/advance rides in per-lane ``pos``/``n_valid`` vectors,
    so one cache serves mixed prefill/decode ticks; the per-slot ``a1_sig``
    buffer is refreshed by block 0 at each lane's own last valid position
    (held for lanes sitting a tick out).

    ``kv_dtype`` selects the quantized KV page format ("" | "bf16" |
    "int8" | "fp8" — see ``attention.gqa_init_paged_cache``): int8/fp8
    pools carry per-page-row fp32 ``k_scale``/``v_scale`` pools that ride
    every downstream tree_map (stacked-layer broadcast, COW page copies,
    the spec-decode draft cache) with no further plumbing."""
    if cfg.family not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"paged KV cache: decoder family only, got {cfg.family}")
    return _decoder_init_paged_cache(cfg, num_pages, page_size, slots, dtype,
                                     kv_dtype=kv_dtype)


def paged_decode_step(params, cfg, batch, cache, plan=None, want="logits"):
    """One paged tick -> (logits, new_cache) in either paged layout:

      * token-PACKED (the serving engine's tick; selected when the batch
        carries ``tok_slot``): a flat (T,) ragged buffer with per-token
        segment ids — see ``_decoder_paged_packed`` for the contract;
        returns (1, T, V) logits / (1, T, D) hidden.
      * padded chunk (kernel/test harness layout): tokens (B, C) with
        per-lane ``pos``/``n_valid`` — see ``_decoder_paged_decode``;
        returns (B, C, V) / (B, C, D).

    ``want='hidden'`` returns the pre-head hidden states instead of logits
    — the serving engine gathers each segment's last row and runs
    ``lm_head`` on (S, 1, D), paying live-segments/T of the head matmul."""
    if cfg.family not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"paged decode: decoder family only, got {cfg.family}")
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.PAGED).validate(cfg)
    if "tok_slot" in batch:
        return _decoder_paged_packed(params, cfg, batch, cache, plan,
                                     want=want)
    return _decoder_paged_decode(params, cfg, batch, cache, plan, want=want)


def lm_head(params, cfg, x):
    """Final norm + (tied) unembedding: hidden (B, S, D) -> logits
    (B, S, V).  The tail ``paged_decode_step(want='hidden')`` callers run
    on their gathered lanes."""
    return _logits(params, cfg, x)


def paged_spec_draft(params, cfg, batch, cache, plan=None, *, draft_blocks=1):
    """Self-speculative DRAFT forward on the token-packed layout: embed ->
    block 0 -> the first ``draft_blocks - 1`` stacked layers, returning
    (hidden (1, T, D), new_cache) — the early-exit stack the serving
    engine's draft loop runs ``lm_head`` over to propose tokens
    (``EngineConfig.draft_blocks``).  Requires 1 <= draft_blocks <
    cfg.n_layers; the batch contract is ``_decoder_paged_packed``'s
    (tokens/tok_slot/tok_pos/block_tables; no seg_last — the caller reads
    the rows it planted).  Draft-layer K/V is scattered; deeper layers and
    the per-slot ``a1_sig`` export are untouched (the verify pass owns
    them)."""
    if cfg.family not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"speculative draft: decoder family only, got {cfg.family}")
    if not 1 <= draft_blocks < cfg.n_layers:
        raise ValueError(
            f"draft_blocks={draft_blocks} must satisfy 1 <= draft_blocks "
            f"< n_layers={cfg.n_layers} (== n_layers would be the full "
            f"model, not a draft)")
    plan = ExecutionPlan.resolve(plan).with_phase(Phase.PAGED).validate(cfg)
    return _decoder_paged_packed_draft(params, cfg, batch, cache, plan,
                                       draft_blocks)


def lm_head_segment_tail(params, cfg, hidden, seg_last, n):
    """Per-segment multi-logit gather + head for speculative VERIFY:
    gather each segment's LAST ``n`` packed rows from ``hidden``
    (1, T, D) — rows ``seg_last[s] - (n-1) .. seg_last[s]`` — and run
    ``lm_head`` on the (S, n, D) gather, paying S*n/T of the full head.

    Returns (logits (S, n, V), rows (S, n) int32).  Lanes sitting the
    tick out (``seg_last == -1``) and gathered indices that would
    underrun row 0 are clamped to row 0 but ZEROED before the head, so
    NaN/garbage in scratch rows can never reach a sampled token —
    callers mask which columns are live (a non-speculative segment's
    only live column is the last)."""
    off = jnp.arange(n, dtype=jnp.int32) - (n - 1)               # (n,)
    rows = seg_last[:, None] + off[None, :]                      # (S, n)
    valid = (seg_last >= 0)[:, None] & (rows >= 0)
    h = hidden[0, jnp.maximum(rows, 0)]                          # (S, n, D)
    h = jnp.where(valid[:, :, None], h, 0.0)
    return _logits(params, cfg, h), rows


def copy_paged_pages(cache, src, dst):
    """Copy-on-write page duplication across EVERY layer's KV pools: the
    page rows at physical pages ``src`` (n,) are copied over pages ``dst``
    (n,) in block 0's pools and all stacked upper-layer pools — the device
    half of ``BlockTable`` COW (the host half swaps the block-table entry).

    The stacked ``blocks`` leaves (L-1, P, page, ...) are copied in ONE
    ``ops.copy_pages`` dispatch each by viewing them as (L-1)*P flat pages
    and offsetting the page ids per layer.  The ``a1_sig`` buffer is
    per-slot, not per-page — untouched.  Callers jit this with the cache
    donated (the Pallas path aliases the pools in place)."""
    from repro.kernels import ops as _ops
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def one(pool):
        return _ops.copy_pages(pool, src, dst)

    def stacked(pool):
        L, P = pool.shape[0], pool.shape[1]
        flat = pool.reshape((L * P,) + pool.shape[2:])
        off = (jnp.arange(L, dtype=jnp.int32) * P)[:, None]
        s = (src[None, :] + off).reshape(-1)
        d = (dst[None, :] + off).reshape(-1)
        return _ops.copy_pages(flat, s, d).reshape(pool.shape)

    new = dict(cache)
    new["block0"] = jax.tree.map(one, cache["block0"])
    new["blocks"] = jax.tree.map(stacked, cache["blocks"])
    return new


def _mtp_loss(p, cfg, batch, hidden):
    """DeepSeek-V3 multi-token prediction: predict t+2 from h_t and emb_{t+1}."""
    tokens = batch["tokens"]
    emb_next = L.embed_apply(p["embed"], tokens[:, 1:], cfg.dtype)
    h = hidden[:, :-1]
    mtp = p["mtp"]
    z = jnp.concatenate([L.norm_apply(mtp["norm_h"], h, cfg.norm),
                         L.norm_apply(mtp["norm_e"], emb_next, cfg.norm)], -1)
    z = L.dense_apply(mtp["proj"], z)
    B, S1 = z.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S1)[None], (B, S1))
    z, _, _, _ = BL.block_apply(mtp["block"], cfg.replace(connection="preln"),
                                z, None, positions, 0, kind="dense")
    logits = _logits(p, cfg, z)                      # (B, S-1, V)
    return cross_entropy(logits[:, :-1], tokens[:, 2:])


def _ce_tail(p, cfg, hidden, tokens):
    logits = _logits(p, cfg, hidden)
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


def loss_fn(params, cfg, batch, plan=None):
    # compute CE from the final hidden state under remat: the (B,S,V)
    # logits (+ their fp32 softmax copies) are recomputed in backward
    # instead of stashed (EXPERIMENTS.md §Perf D2)
    plan = ExecutionPlan.resolve(plan)
    _, aux, extras = forward(params, cfg, batch, plan, want="hidden")
    tokens = batch["tokens"]
    tail = jax.checkpoint(functools.partial(_ce_tail, cfg=cfg)) \
        if cfg.remat else functools.partial(_ce_tail, cfg=cfg)
    ce = tail(params, hidden=extras["hidden"], tokens=tokens)
    loss = ce + cfg.router_aux_coef * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth and "mtp" in params:
        mtp_fn = jax.checkpoint(functools.partial(_mtp_loss, cfg=cfg)) \
            if cfg.remat else functools.partial(_mtp_loss, cfg=cfg)
        mtp = mtp_fn(params, batch=batch, hidden=extras["hidden"])
        loss = loss + 0.3 * mtp
        metrics["mtp"] = mtp
    return loss, metrics
