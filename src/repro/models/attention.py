"""Attention: GQA (+qk_norm, sliding window, logit softcap), MLA (DeepSeek),
blockwise flash-style jnp implementation, and KV-cache decode paths.

The blockwise implementation is the dry-run/compile path (Pallas kernels do
not lower on the CPU host backend); the Pallas TPU kernel in
``repro.kernels.flash_attention`` implements the same online-softmax algorithm
and is validated against ``ref.py`` in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


# ------------------------------------------------------------------------- #
# core blockwise attention (training / prefill)
# ------------------------------------------------------------------------- #
def blockwise_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                        block_q=512, scale=None, q_offset=0):
    """Flash-style attention, scanning over query blocks.

    q: (B, Sq, H, Dh)   k: (B, Sk, Hkv, Dh)   v: (B, Sk, Hkv, Dv)
    Memory: O(block_q * Sk) scores instead of O(Sq * Sk).
    ``q_offset``: position of q[0] within the key sequence (cross-attention /
    chunked prefill).
    """
    B, Sq, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    Dv = v.shape[-1]
    scale = Dh ** -0.5 if scale is None else scale

    bq = min(block_q, Sq)
    pad = (-Sq) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = q.shape[1] // bq
    qb = q.reshape(B, nblk, bq, Hkv, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    k_pos = jnp.arange(Sk)

    def one_block(i, qblk):
        # qblk: (B, bq, Hkv, G, Dh)
        q_pos = q_offset + i * bq + jnp.arange(bq)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, k,
                       preferred_element_type=jnp.float32) * scale
        s = L.softcap(s, cap)
        mask = jnp.ones((bq, Sk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if not (isinstance(window, int) and window == 0):
            # ``window`` may be a traced per-layer scalar (scan over layers);
            # window <= 0 disables the mask.
            w = jnp.asarray(window)
            mask &= (k_pos[None, :] > q_pos[:, None] - w) | (w <= 0)
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
        return o  # (B, bq, Hkv, G, Dv)

    out = jax.lax.map(lambda args: one_block(*args),
                      (jnp.arange(nblk), qb))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nblk * bq, H, Dv)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, pos, *, window=0, cap=0.0,
                     scale=None):
    """One-token attention against a KV cache.

    q: (B, 1, H, Dh); caches: (B, S, Hkv, D*); pos: (B,) int32 index of the
    current token (keys at indices <= pos are valid).
    """
    B, _, H, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = Dh ** -0.5 if scale is None else scale
    qg = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = L.softcap(s, cap)
    k_pos = jnp.arange(S)[None]                        # (1, S)
    mask = k_pos <= pos[:, None]
    if not (isinstance(window, int) and window == 0):
        w = jnp.asarray(window)
        mask &= (k_pos > (pos[:, None] - w)) | (w <= 0)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, v_cache.shape[-1])


# ------------------------------------------------------------------------- #
# paged KV cache (serving engine)
# ------------------------------------------------------------------------- #
# The serving engine stores KV state in fixed-size pages: a pool shaped
# (num_pages, page_size, ...) plus a per-request block table mapping logical
# block j (positions j*page_size .. (j+1)*page_size - 1) to a physical page.
# Page 0 is a scratch page owned by no request: masked lanes of padded
# prefill chunks are redirected there, so ragged batches never corrupt live
# pages.  Writes are idempotent per (request, position) — re-decoding the
# same position overwrites the same slot (the engine relies on this for
# preemption -> resume).


def paged_scatter(pages, vals, block_tables, pos, n_valid, page_size):
    """Write a (B, C, ...) chunk of per-token values into the page pool.

    pages: (P, page_size, ...); vals: (B, C, ...); block_tables: (B, T);
    pos: (B,) logical position of each request's first chunk token;
    n_valid: (B,) number of valid tokens in the chunk (rest -> scratch page).
    """
    B, C = vals.shape[:2]
    T = block_tables.shape[1]
    lpos = pos[:, None] + jnp.arange(C)[None]                     # (B, C)
    blk = jnp.clip(lpos // page_size, 0, T - 1)
    pg = jnp.take_along_axis(block_tables, blk, axis=1)           # (B, C)
    valid = jnp.arange(C)[None] < n_valid[:, None]
    pg = jnp.where(valid, pg, 0)                                  # scratch
    flat_idx = (pg * page_size + lpos % page_size).reshape(-1)
    flat = pages.reshape((pages.shape[0] * page_size,) + pages.shape[2:])
    flat = flat.at[flat_idx].set(
        vals.reshape((B * C,) + vals.shape[2:]).astype(pages.dtype))
    return flat.reshape(pages.shape)


def packed_scatter(pages, vals, block_tables, tok_slot, tok_pos, page_size):
    """Write a flat (T, ...) packed token buffer into the page pool.

    pages: (P, page_size, ...); vals: (T, ...) one value per packed token;
    block_tables: (S, Tb) per-SLOT tables; tok_slot/tok_pos: (T,) — token t
    belongs to lane ``tok_slot[t]`` at logical position ``tok_pos[t]``.
    Padding tokens carry tok_pos == -1 and are redirected to the scratch
    page (page 0), so ragged packs never corrupt live pages.
    """
    Tb = block_tables.shape[1]
    pos = jnp.maximum(tok_pos, 0)
    blk = jnp.clip(pos // page_size, 0, Tb - 1)
    pg = block_tables[tok_slot, blk]                              # (T,)
    pg = jnp.where(tok_pos >= 0, pg, 0)                           # scratch
    flat_idx = pg * page_size + pos % page_size
    flat = pages.reshape((pages.shape[0] * page_size,) + pages.shape[2:])
    flat = flat.at[flat_idx].set(vals.astype(pages.dtype))
    return flat.reshape(pages.shape)


def paged_gather(pages, block_tables):
    """(P, page_size, ...) x (B, T) -> (B, T*page_size, ...): the request's
    logical KV sequence (gathered index == logical position)."""
    g = pages[block_tables]
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def _gather_dequant(pages, scale_pages, block_tables):
    """Gather a K/V pool to (B, Sk, Hkv, D) and, when a per-page-row scale
    pool rides along (quantized KV), dequantize to fp32 — the masked-gather
    fallback's mirror of the kernels' in-VMEM dequant."""
    g = paged_gather(pages, block_tables)
    if scale_pages is None:
        return g
    s = paged_gather(scale_pages, block_tables)                   # (B, Sk)
    return g.astype(jnp.float32) * s[..., None, None]


def chunk_attention(q, k, v, q_pos, *, window=0, cap=0.0, scale=None):
    """Multi-token attention against a gathered cache with per-request
    positions (chunked prefill / paged decode).

    q: (B, C, H, Dh); k, v: (B, Sk, Hkv, D*); q_pos: (B, C) global position
    of each query token.  Key at gathered index j is visible iff j <= q_pos.
    """
    B, C, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = Dh ** -0.5 if scale is None else scale
    qg = q.reshape(B, C, Hkv, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = L.softcap(s, cap)
    k_pos = jnp.arange(Sk)[None, None]                            # (1,1,Sk)
    mask = k_pos <= q_pos[:, :, None]
    if not (isinstance(window, int) and window == 0):
        w = jnp.asarray(window)
        mask &= (k_pos > q_pos[:, :, None] - w) | (w <= 0)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, C, H, v.shape[-1])


#: quantized KV page storage dtypes (``EngineConfig.kv_dtype`` values);
#: "" keeps the engine's ``cache_dtype`` pools (bit-preserved legacy path)
KV_DTYPES = ("", "bf16", "int8", "fp8")
_KV_STORAGE = {"bf16": "bfloat16", "int8": "int8", "fp8": "float8_e4m3fn"}


def _kv_qmax(pages_dtype) -> float:
    return 448.0 if jnp.dtype(pages_dtype).name.startswith("float8") else 127.0


def _quant_rows(vals, pages_dtype):
    """Per-token-row KV quantization: vals (..., Hkv, Dh) -> (q, scale)
    with ``scale = amax / qmax`` reduced over (Hkv, Dh) — ONE fp32 scale
    per cached token row, shared across KV heads.  History-free by
    construction (a row's scale depends only on that row's values), so
    re-scattering a position is idempotent and a COW'd page is
    bit-identical to its source — the properties the prefix-cache and
    spec-rollback identity tests pin.  ``q`` is returned in fp32 units of
    the narrow grid (int grids pre-rounded and clipped); the page
    scatter's ``astype(pages.dtype)`` performs the final cast."""
    qmax = _kv_qmax(pages_dtype)
    a = jnp.max(jnp.abs(vals.astype(jnp.float32)), axis=(-2, -1))
    scale = jnp.maximum(a / qmax, 1e-8)
    q = vals.astype(jnp.float32) / scale[..., None, None]
    if not jnp.dtype(pages_dtype).name.startswith("float8"):
        q = jnp.clip(jnp.round(q), -qmax, qmax)
    return q, scale


def gqa_init_paged_cache(cfg, num_pages, page_size, dtype, kv_dtype=""):
    """K/V page pools (P, page, Hkv, Dh).  ``kv_dtype`` selects the
    quantized page format: "" stores in ``dtype`` (the engine's
    ``cache_dtype`` — existing path, bit-preserved); "bf16" stores
    bfloat16 with no scales; "int8"/"fp8" store the narrow dtype plus
    per-page-row (P, page) fp32 ``k_scale``/``v_scale`` pools shared
    across KV heads — 2 bytes/elt -> 1 byte/elt + 4 bytes/row."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                         f"got {kv_dtype!r}")
    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    store = jnp.dtype(_KV_STORAGE.get(kv_dtype, dtype))
    cache = {
        "k": jnp.zeros((num_pages, page_size, Hkv, Dh), store),
        "v": jnp.zeros((num_pages, page_size, Hkv, Dh), store),
    }
    if kv_dtype in ("int8", "fp8"):
        cache["k_scale"] = jnp.ones((num_pages, page_size), jnp.float32)
        cache["v_scale"] = jnp.ones((num_pages, page_size), jnp.float32)
    return cache


def _gqa_paged_qkv_scatter(p, cfg, x, cache, block_tables, pos, n_valid):
    """Shared prologue of the sequential and dual-branch paged paths:
    project q/k/v at the chunk's positions and scatter k/v into the page
    pools (quantizing per token row first when the cache carries
    ``k_scale``/``v_scale`` pools).  Returns (q, new_cache, positions) —
    ONE implementation so the two paths cannot drift apart (they are
    asserted bit-identical)."""
    C = x.shape[1]
    page = cache["k"].shape[1]
    positions = pos[:, None] + jnp.arange(C)[None]
    q, k, v = gqa_qkv(p, cfg, x, positions)
    nc = {}
    if "k_scale" in cache:
        k, ks = _quant_rows(k, cache["k"].dtype)
        v, vs = _quant_rows(v, cache["v"].dtype)
        nc["k_scale"] = paged_scatter(cache["k_scale"], ks, block_tables,
                                      pos, n_valid, page)
        nc["v_scale"] = paged_scatter(cache["v_scale"], vs, block_tables,
                                      pos, n_valid, page)
    nc["k"] = paged_scatter(cache["k"], k, block_tables, pos, n_valid, page)
    nc["v"] = paged_scatter(cache["v"], v, block_tables, pos, n_valid, page)
    return q, nc, positions


def gqa_paged_apply(p, cfg, x, cache, block_tables, pos, n_valid, *,
                    window=0):
    """Chunked decode/prefill against a paged cache.  x: (B, C, D) with C >= 1
    (C == 1 is a decode-only tick; C > 1 serves lanes at ANY phase — per-lane
    ``pos``/``n_valid`` let prefilling lanes advance up to C positions while
    decoding lanes advance 1 in the same dispatch).  Returns
    (out (B,C,D), new_cache)."""
    B, C = x.shape[:2]
    q, nc, positions = _gqa_paged_qkv_scatter(p, cfg, x, cache,
                                              block_tables, pos, n_valid)
    kc, vc = nc["k"], nc["v"]
    ks, vs = nc.get("k_scale"), nc.get("v_scale")
    if cfg.attn_softcap == 0.0 and isinstance(window, int) and window == 0:
        # full-attention tick: the block-table kernel paths (Pallas on TPU,
        # gather-based ref on CPU) — the TPU kernels DMA pages directly so
        # no gathered (B, T*page) copy is ever materialised in HBM; scale
        # pools (quantized KV) ride the same block tables and dequantize
        # inside the kernel's VMEM load
        from repro.kernels import ops
        if C == 1:
            o = ops.paged_decode_attention(q[:, 0], kc, vc, block_tables,
                                           pos + 1, k_scale=ks,
                                           v_scale=vs)[:, None]
        else:
            o = ops.paged_chunk_attention(q, kc, vc, block_tables, pos,
                                          n_valid, k_scale=ks, v_scale=vs)
    else:
        # sliding-window / softcapped layers (gemma2): masked gather path
        o = chunk_attention(q, _gather_dequant(kc, ks, block_tables),
                            _gather_dequant(vc, vs, block_tables), positions,
                            window=window, cap=cfg.attn_softcap)
        if ks is not None:
            o = o.astype(x.dtype)
    return o.reshape(B, C, -1) @ p["wo"].astype(x.dtype), nc


def gqa_paged_dual(p, ffn, cfg, x, mlp_in, cache, block_tables, pos,
                   n_valid):
    """Dual-branch single-token paged tick: the block-table attention gather
    and the dense FFN matmuls go down as ONE fused dispatch
    (``kernels.ops.dual_branch_decode``) so the TPU overlaps page DMAs with
    FFN MXU work; the CPU fallback runs exactly the sequential path's ops
    (gather-free ref attention + ``layers.mlp_apply``), keeping dual-branch
    logits bit-identical to sequential decode.

    x: (B, 1, D) post-ln1 attention input; mlp_in: (B, 1, D) the block's
    MLP input (independent of this block's attention — the FAL property).
    Returns (attn_out (B,1,D), ffn_out (B,1,D), new_cache).
    """
    B, C = x.shape[:2]
    q, nc, _ = _gqa_paged_qkv_scatter(p, cfg, x, cache, block_tables,
                                      pos, n_valid)
    from repro.kernels import ops
    if "k_scale" in nc:
        # quantized KV: the fused dual-branch kernel has no dequant path,
        # so issue the two branches as independent ops (XLA still overlaps
        # them) — the scale-aware paged kernel + the dense MLP
        o = ops.paged_decode_attention(q[:, 0], nc["k"], nc["v"],
                                       block_tables, pos + 1,
                                       k_scale=nc["k_scale"],
                                       v_scale=nc["v_scale"])
        y = L.mlp_apply(ffn, mlp_in, cfg.mlp)
    else:
        o, y = ops.dual_branch_decode(q[:, 0], nc["k"], nc["v"],
                                      block_tables, pos + 1, mlp_in, ffn,
                                      kind=cfg.mlp)
    a = o[:, None].reshape(B, C, -1) @ p["wo"].astype(x.dtype)
    return a, y, nc


def gqa_packed_apply(p, cfg, x, cache, block_tables, tok_slot, tok_pos, *,
                     window=0):
    """Token-packed ragged tick against a paged cache.  x: (1, T, D) — one
    flat buffer of packed tokens where token t belongs to lane
    ``tok_slot[t]`` at logical position ``tok_pos[t]`` (padding tokens at
    tok_pos == -1 scatter to scratch and yield meaningless rows that
    callers must not read).  A prefilling lane contributes up to ``chunk``
    contiguous tokens, a decoding lane exactly one, in the SAME dispatch —
    FLOPs scale with live tokens, not slots x chunk.  Returns
    (out (1,T,D), new_cache)."""
    B, T = x.shape[:2]
    page = cache["k"].shape[1]
    positions = jnp.maximum(tok_pos, 0)[None]                     # (1, T)
    q, k, v = gqa_qkv(p, cfg, x, positions)                       # (1,T,H,Dh)
    k, v = k[0], v[0]
    nc = {}
    if "k_scale" in cache:
        k, ks_rows = _quant_rows(k, cache["k"].dtype)
        v, vs_rows = _quant_rows(v, cache["v"].dtype)
        nc["k_scale"] = packed_scatter(cache["k_scale"], ks_rows,
                                       block_tables, tok_slot, tok_pos, page)
        nc["v_scale"] = packed_scatter(cache["v_scale"], vs_rows,
                                       block_tables, tok_slot, tok_pos, page)
    nc["k"] = packed_scatter(cache["k"], k, block_tables, tok_slot, tok_pos,
                             page)
    nc["v"] = packed_scatter(cache["v"], v, block_tables, tok_slot, tok_pos,
                             page)
    kc, vc = nc["k"], nc["v"]
    ks, vs = nc.get("k_scale"), nc.get("v_scale")
    if cfg.attn_softcap == 0.0 and isinstance(window, int) and window == 0:
        # full-attention tick: the segment-aware block-table kernel (Pallas
        # on TPU DMAs each token's OWN pages; gather-based ref on CPU);
        # quantized pages dequantize in-kernel via the scale pools
        from repro.kernels import ops
        o = ops.paged_packed_attention(q[0], kc, vc, block_tables,
                                       tok_slot, tok_pos, k_scale=ks,
                                       v_scale=vs)[None]
    else:
        # sliding-window / softcapped layers (gemma2): per-token masked
        # gather — each token indexes its own slot's gathered sequence
        kg = _gather_dequant(kc, ks, block_tables)[tok_slot]      # (T,Sk,..)
        vg = _gather_dequant(vc, vs, block_tables)[tok_slot]
        o = chunk_attention(q[0][:, None], kg, vg, tok_pos[:, None],
                            window=window, cap=cfg.attn_softcap)[:, 0][None]
        if ks is not None:
            o = o.astype(x.dtype)
    return o.reshape(B, T, -1) @ p["wo"].astype(x.dtype), nc


# ------------------------------------------------------------------------- #
# GQA module
# ------------------------------------------------------------------------- #
def gqa_init(key, cfg, cross=False):
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": L.dense_init(ks[0], d, H * Dh, cfg.param_dtype)["w"],
        "wk": L.dense_init(ks[1], d, Hkv * Dh, cfg.param_dtype)["w"],
        "wv": L.dense_init(ks[2], d, Hkv * Dh, cfg.param_dtype)["w"],
        "wo": L.dense_init(ks[3], H * Dh, d, cfg.param_dtype,
                           scale=1.0 / np.sqrt(H * Dh * 2 * cfg.n_layers))["w"],
    }
    if cfg.qk_norm:
        p["qnorm"] = L.norm_init(Dh, "rmsnorm", cfg.param_dtype)
        p["knorm"] = L.norm_init(Dh, "rmsnorm", cfg.param_dtype)
    return p


def gqa_qkv(p, cfg, x, positions, kv_x=None, rope=True):
    """Project to q,k,v (with qk_norm + rope).

    Head counts are derived from the WEIGHT shapes, not cfg: inside the
    explicit-TP shard_map (model.decoder_stack_tp) each device holds a
    head-aligned column slice of wq/wk/wv, so the same code is the local
    kernel over H/tp heads — and, with the row-sharded ``wo`` downstream,
    yields the per-device partial sum of the paper's Fig 2."""
    Dh = cfg.resolved_head_dim
    H, Hkv = p["wq"].shape[-1] // Dh, p["wk"].shape[-1] // Dh
    B, S = x.shape[:2]
    kv_x = x if kv_x is None else kv_x
    Skv = kv_x.shape[1]
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    k = (kv_x @ p["wk"].astype(x.dtype)).reshape(B, Skv, Hkv, Dh)
    v = (kv_x @ p["wv"].astype(x.dtype)).reshape(B, Skv, Hkv, Dh)
    if cfg.qk_norm:
        q = L.norm_apply(p["qnorm"], q)
        k = L.norm_apply(p["knorm"], k)
    if cfg.rope and rope and positions is not None:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def sequence_parallel_attention(q, k, v, cfg, plan, *, causal=True,
                                window=0):
    """Context-parallel attention (beyond-paper, EXPERIMENTS.md §Perf P1).

    q is sharded on the sequence dim over the ``model`` axis; K/V are
    gathered (jit inserts the all-gather at the shard_map boundary).  Each
    shard runs blockwise attention over its local q rows with the correct
    global ``q_offset`` for causal/window masks.  Removes the score-matmul
    all-reduces GSPMD emits when kv-heads don't divide the model axis.
    """
    from jax.sharding import PartitionSpec as P
    mesh, dax, max_ = plan.mesh, plan.data_axes, plan.model_axis
    M = mesh.shape[max_]
    S = q.shape[1]
    assert S % M == 0, (S, M)

    def local(q_loc, k_full, v_full, w):
        off = jax.lax.axis_index(max_) * (S // M)
        return blockwise_attention(
            q_loc, k_full, v_full, causal=causal, window=w,
            cap=cfg.attn_softcap, block_q=min(cfg.attn_block_q, S // M),
            q_offset=off)

    from repro.core.compat import shard_map
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(dax, max_, None, None),
                             P(dax, None, None, None),
                             P(dax, None, None, None), P()),
                   out_specs=P(dax, max_, None, None),
                   check_vma=False)
    # window may be a traced per-layer scalar (scan xs) — pass explicitly
    return fn(q, k, v, jnp.asarray(window, jnp.int32))


def _use_seq_parallel(cfg, plan, S):
    """Context-parallel attention opt-in (cfg.attn_shard == 'sequence');
    distinct from plan.sequence_parallel (Megatron-SP LN regions, which
    runs inside the explicit-TP shard_map where plan.mesh is None)."""
    if cfg.attn_shard != "sequence" or plan is None or plan.mesh is None:
        return False
    return S % plan.mesh.shape[plan.model_axis] == 0


def _kv_group_slice(k, v, cfg, plan):
    """Megatron GQA fallback for n_kv_heads < tp_size inside the explicit-TP
    shard_map: wk/wv arrive REPLICATED (launch.mesh kv_replicated specs),
    every device computes all KV heads cheaply and slices the one its query
    heads attend to (tp_size/n_kv_heads devices share each KV head)."""
    if plan is None or plan.tp_axis is None:
        return k, v
    tp = plan.tp_size
    if cfg.n_kv_heads % tp == 0:
        return k, v          # kv heads are sharded like query heads
    rep = tp // cfg.n_kv_heads
    idx = jax.lax.axis_index(plan.tp_axis) // rep
    return (jax.lax.dynamic_slice_in_dim(k, idx, 1, axis=2),
            jax.lax.dynamic_slice_in_dim(v, idx, 1, axis=2))


def gqa_apply(p, cfg, x, positions, *, window=0, causal=True, plan=None):
    """Full-sequence attention (train / prefill). Returns (B,S,D) — a TP
    partial sum when the weights are the explicit-TP shards."""
    q, k, v = gqa_qkv(p, cfg, x, positions)
    k, v = _kv_group_slice(k, v, cfg, plan)
    B, S = x.shape[:2]
    if _use_seq_parallel(cfg, plan, S):
        o = sequence_parallel_attention(q, k, v, cfg, plan, causal=causal,
                                        window=window)
    else:
        o = blockwise_attention(q, k, v, causal=causal, window=window,
                                cap=cfg.attn_softcap, block_q=cfg.attn_block_q)
    return o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)


def gqa_cross_apply(p, cfg, x, enc_out):
    """Cross-attention (whisper decoder): no causal mask, no rope."""
    q, k, v = gqa_qkv(p, cfg, x, None, kv_x=enc_out, rope=False)
    o = blockwise_attention(q, k, v, causal=False, cap=0.0,
                            block_q=cfg.attn_block_q)
    B, S = x.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)


def gqa_init_cache(cfg, batch, seq, dtype):
    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, seq, Hkv, Dh), jnp.dtype(dtype)),
        "v": jnp.zeros((batch, seq, Hkv, Dh), jnp.dtype(dtype)),
    }


def gqa_decode(p, cfg, x, cache, pos, *, window=0):
    """One-step decode. x: (B,1,D); pos: (B,) current position. Returns
    (out, new_cache)."""
    B = x.shape[0]
    q, k, v = gqa_qkv(p, cfg, x, pos[:, None].astype(jnp.int32))
    upd = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(c, u, i, 0))
    kc = upd(cache["k"], k.astype(cache["k"].dtype), pos)
    vc = upd(cache["v"], v.astype(cache["v"].dtype), pos)
    o = decode_attention(q, kc, vc, pos, window=window, cap=cfg.attn_softcap)
    out = o.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return out, {"k": kc, "v": vc}


# ------------------------------------------------------------------------- #
# MLA (DeepSeek-V3 Multi-head Latent Attention)
# ------------------------------------------------------------------------- #
def mla_init(key, cfg):
    d, H = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "w_dq": L.dense_init(ks[0], d, rq, cfg.param_dtype)["w"],
        "q_norm": L.norm_init(rq, "rmsnorm", cfg.param_dtype),
        "w_uq": L.dense_init(ks[1], rq, H * (dn + dr), cfg.param_dtype)["w"],
        "w_dkv": L.dense_init(ks[2], d, rkv, cfg.param_dtype)["w"],
        "kv_norm": L.norm_init(rkv, "rmsnorm", cfg.param_dtype),
        "w_kr": L.dense_init(ks[3], d, dr, cfg.param_dtype)["w"],
        "w_uk": L.dense_init(ks[4], rkv, H * dn, cfg.param_dtype)["w"],
        "w_uv": L.dense_init(ks[5], rkv, H * dv, cfg.param_dtype)["w"],
        "wo": L.dense_init(ks[6], H * dv, d, cfg.param_dtype,
                           scale=1.0 / np.sqrt(H * dv * 2 * cfg.n_layers))["w"],
    }
    return p


def _mla_q(p, cfg, x, positions):
    B, S = x.shape[:2]
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    # head count from the weight shape: w_uq may be a column (head) shard
    # inside the explicit-TP shard_map (same contract as gqa_qkv)
    H = p["w_uq"].shape[-1] // (dn + dr)
    cq = L.norm_apply(p["q_norm"], x @ p["w_dq"].astype(x.dtype))
    q = (cq @ p["w_uq"].astype(x.dtype)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, cfg, x, positions):
    c = L.norm_apply(p["kv_norm"], x @ p["w_dkv"].astype(x.dtype))
    kr = x @ p["w_kr"].astype(x.dtype)                       # (B,S,dr), shared head
    kr = L.apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c, kr


def mla_apply(p, cfg, x, positions, plan=None):
    """Full-sequence MLA (train / prefill): expand k,v; blockwise attention.

    Like gqa_apply, head count comes from the (possibly head-sharded)
    up-projection weights; with the row-sharded ``wo`` the result is then a
    TP partial sum."""
    B, S = x.shape[:2]
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    H = p["w_uk"].shape[-1] // dn
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c, kr = _mla_ckv(p, cfg, x, positions)
    k_nope = (c @ p["w_uk"].astype(x.dtype)).reshape(B, S, H, dn)
    v = (c @ p["w_uv"].astype(x.dtype)).reshape(B, S, H, dv)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                                  (B, S, H, dr))], -1)
    scale = (dn + dr) ** -0.5
    if _use_seq_parallel(cfg, plan, S):
        # note: v head dim != qk head dim is fine (shard_map is shape-blind)
        o = sequence_parallel_attention(q, k, v, cfg, plan, causal=True)
    else:
        o = blockwise_attention(q, k, v, causal=True, scale=scale,
                                block_q=cfg.attn_block_q)
    return o.reshape(B, S, H * dv) @ p["wo"].astype(x.dtype)


def mla_init_cache(cfg, batch, seq, dtype):
    return {
        "c": jnp.zeros((batch, seq, cfg.kv_lora_rank), jnp.dtype(dtype)),
        "kr": jnp.zeros((batch, seq, cfg.qk_rope_head_dim), jnp.dtype(dtype)),
    }


def mla_decode(p, cfg, x, cache, pos):
    """Absorbed-matrix MLA decode: attention in the compressed latent space.
    Cache holds only (c, k_rope) per token — the reason long_500k is feasible.
    """
    B = x.shape[0]
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    positions = pos[:, None].astype(jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)            # (B,1,H,dn/dr)
    c, kr = _mla_ckv(p, cfg, x, positions)                   # (B,1,rkv), (B,1,dr)
    upd = jax.vmap(lambda cc, u, i: jax.lax.dynamic_update_slice_in_dim(cc, u, i, 0))
    cc = upd(cache["c"], c.astype(cache["c"].dtype), pos)
    krc = upd(cache["kr"], kr.astype(cache["kr"].dtype), pos)
    # absorb W_uk into q:  q_lat[h] = q_nope[h] @ W_uk[:, h, :].T
    w_uk = p["w_uk"].astype(x.dtype).reshape(rkv, H, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)   # (B,H,rkv)
    s = jnp.einsum("bhr,bkr->bhk", q_lat, cc,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bhd,bkd->bhk", q_rope[:, 0], krc,
                    preferred_element_type=jnp.float32)
    s *= (dn + dr) ** -0.5
    mask = jnp.arange(cc.shape[1])[None] <= pos[:, None]
    s = jnp.where(mask[:, None], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhk,bkr->bhr", pattn.astype(cc.dtype), cc)  # (B,H,rkv)
    w_uv = p["w_uv"].astype(x.dtype).reshape(rkv, H, dv)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv).reshape(B, 1, H * dv)
    return o @ p["wo"].astype(x.dtype), {"c": cc, "kr": krc}


def mla_init_paged_cache(cfg, num_pages, page_size, dtype):
    return {
        "c": jnp.zeros((num_pages, page_size, cfg.kv_lora_rank),
                       jnp.dtype(dtype)),
        "kr": jnp.zeros((num_pages, page_size, cfg.qk_rope_head_dim),
                        jnp.dtype(dtype)),
    }


def mla_paged_apply(p, cfg, x, cache, block_tables, pos, n_valid):
    """Chunked absorbed-matrix MLA decode against paged (c, k_rope) pages.
    x: (B, C, D); returns (out (B,C,D), new_cache)."""
    B, C = x.shape[:2]
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    page = cache["c"].shape[1]
    positions = pos[:, None] + jnp.arange(C)[None]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)            # (B,C,H,dn/dr)
    c, kr = _mla_ckv(p, cfg, x, positions)                   # (B,C,rkv/dr)
    c_pool = paged_scatter(cache["c"], c, block_tables, pos, n_valid, page)
    kr_pool = paged_scatter(cache["kr"], kr, block_tables, pos, n_valid, page)
    cc = paged_gather(c_pool, block_tables)                  # (B,Sk,rkv)
    krc = paged_gather(kr_pool, block_tables)                # (B,Sk,dr)
    w_uk = p["w_uk"].astype(x.dtype).reshape(rkv, H, dn)
    q_lat = jnp.einsum("bchd,rhd->bchr", q_nope, w_uk)       # (B,C,H,rkv)
    s = jnp.einsum("bchr,bkr->bhck", q_lat, cc,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bchd,bkd->bhck", q_rope, krc,
                    preferred_element_type=jnp.float32)
    s *= (dn + dr) ** -0.5
    mask = jnp.arange(cc.shape[1])[None, None] <= positions[:, :, None]
    s = jnp.where(mask[:, None], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhck,bkr->bchr", pattn.astype(cc.dtype), cc)
    w_uv = p["w_uv"].astype(x.dtype).reshape(rkv, H, dv)
    o = jnp.einsum("bchr,rhd->bchd", o_lat, w_uv).reshape(B, C, H * dv)
    return o @ p["wo"].astype(x.dtype), {"c": c_pool, "kr": kr_pool}


def mla_packed_apply(p, cfg, x, cache, block_tables, tok_slot, tok_pos):
    """Token-packed absorbed-matrix MLA against paged (c, k_rope) pages.
    x: (1, T, D) packed buffer (see ``gqa_packed_apply`` for the token/
    segment contract); each token attends its OWN slot's gathered latent
    sequence.  Returns (out (1,T,D), new_cache)."""
    B, T = x.shape[:2]
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    page = cache["c"].shape[1]
    positions = jnp.maximum(tok_pos, 0)[None]                     # (1, T)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)                 # (1,T,H,*)
    c, kr = _mla_ckv(p, cfg, x, positions)                        # (1,T,*)
    c_pool = packed_scatter(cache["c"], c[0], block_tables, tok_slot,
                            tok_pos, page)
    kr_pool = packed_scatter(cache["kr"], kr[0], block_tables, tok_slot,
                             tok_pos, page)
    cc = paged_gather(c_pool, block_tables)[tok_slot]             # (T,Sk,rkv)
    krc = paged_gather(kr_pool, block_tables)[tok_slot]           # (T,Sk,dr)
    w_uk = p["w_uk"].astype(x.dtype).reshape(rkv, H, dn)
    q_lat = jnp.einsum("thd,rhd->thr", q_nope[0], w_uk)           # (T,H,rkv)
    s = jnp.einsum("thr,tkr->thk", q_lat, cc,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("thd,tkd->thk", q_rope[0], krc,
                    preferred_element_type=jnp.float32)
    s *= (dn + dr) ** -0.5
    mask = jnp.arange(cc.shape[1])[None] <= tok_pos[:, None]      # (T, Sk)
    s = jnp.where(mask[:, None], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("thk,tkr->thr", pattn.astype(cc.dtype), cc)
    w_uv = p["w_uv"].astype(x.dtype).reshape(rkv, H, dv)
    o = jnp.einsum("thr,rhd->thd", o_lat, w_uv).reshape(B, T, H * dv)
    return o @ p["wo"].astype(x.dtype), {"c": c_pool, "kr": kr_pool}
