"""Shared primitive layers: norms, RoPE, MLPs, embeddings, softcap.

Pure-functional style: ``*_init(key, ...) -> params`` and
``*_apply(params, x, ...) -> y``.  Params are plain dict pytrees so they can
be stacked with vmap for lax.scan-over-layers and mirrored by PartitionSpec
trees (see launch/mesh.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name):
    return jnp.dtype(name)


# ----------------------------------------------------------------- norms ----
def norm_init(d, kind="rmsnorm", dtype="float32"):
    p = {"scale": jnp.ones((d,), _dtype(dtype))}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), _dtype(dtype))
    return p


def norm_apply(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------------------ rope ----
def rope_freqs(head_dim, theta=10000.0):
    half = head_dim // 2
    inv = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    return jnp.asarray(inv)  # (half,)


def apply_rope(x, positions, theta=10000.0):
    """x: (..., S, H, D) ; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, half)
    ang = ang[..., None, :]  # (..., S, 1, half) broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- softcap ----
def softcap(x, cap):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------- linear ----
def dense_init(key, d_in, d_out, dtype="float32", scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), _dtype(dtype)) * scale
    return {"w": w}


def dense_apply(p, x):
    return x @ p["w"].astype(x.dtype)


# ------------------------------------------------------------------- mlp ----
def mlp_init(key, d, d_ff, kind="swiglu", dtype="float32", out_scale=None):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {}
    if kind in ("swiglu", "geglu"):
        p["wi"] = dense_init(k1, d, d_ff, dtype)["w"]
        p["wg"] = dense_init(k2, d, d_ff, dtype)["w"]
    else:  # gelu
        p["wi"] = dense_init(k1, d, d_ff, dtype)["w"]
    p["wo"] = dense_init(k3, d_ff, d, dtype, scale=out_scale or 1.0 / np.sqrt(d_ff))["w"]
    return p


def mlp_apply(p, x, kind="swiglu"):
    w_i = p["wi"].astype(x.dtype)
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ w_i)
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["wg"].astype(x.dtype)) * (x @ w_i)
    else:
        h = jax.nn.gelu(x @ w_i)
    return h @ p["wo"].astype(x.dtype)


# -------------------------------------------------------------- embedding ----
def embed_init(key, vocab, d, dtype="float32"):
    return {"emb": jax.random.normal(key, (vocab, d), _dtype(dtype)) * 0.02}


def embed_apply(p, tokens, dtype):
    return p["emb"].astype(_dtype(dtype))[tokens]


def unembed_apply(p, x, final_cap=0.0):
    logits = x @ p["emb"].astype(x.dtype).T
    return softcap(logits, final_cap)
