"""Token data pipeline.

Two sources:
* ``SyntheticMarkov`` — deterministic, learnable synthetic LM corpus (sparse
  Markov chain over the vocab).  A model that learns the transition table
  drives loss well below the unigram entropy, so quality benchmarks
  (bench_quality, paper Table 1 / Fig 9 analogues) produce meaningful curves
  without external datasets.
* ``MemmapTokens`` — production path: flat uint16/uint32 token file, memory
  mapped, sharded across hosts by ``(host_id, num_hosts)``.

Both yield dict batches ``{"tokens": (B, S) int32}`` deterministically from a
seed + step index (restart-safe: the stream is a pure function of the step).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticMarkov:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    branching: int = 4
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse transition table: each token -> `branching` successors
        self.table = rng.integers(0, self.vocab,
                                  size=(self.vocab, self.branching))
        probs = rng.random((self.vocab, self.branching)) + 0.1
        self.probs = probs / probs.sum(1, keepdims=True)

    def batch_at(self, step: int):
        rng = np.random.default_rng(
            (self.seed, step, self.host_id))
        B, S = self.batch // self.num_hosts, self.seq_len
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, B)
        # vectorised Markov walk
        choices = rng.random((B, S))
        for t in range(1, S):
            cum = np.cumsum(self.probs[toks[:, t - 1]], axis=1)
            idx = (choices[:, t:t + 1] > cum).sum(1)
            toks[:, t] = self.table[toks[:, t - 1], idx]
        return {"tokens": toks}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class MemmapTokens:
    path: str
    seq_len: int
    batch: int
    seed: int = 0
    dtype: str = "uint16"
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        self.data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self.n_seqs = (len(self.data) - 1) // self.seq_len

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        B = self.batch // self.num_hosts
        # every host draws the same global permutation, takes its slice
        idx = rng.integers(0, self.n_seqs, self.batch)
        idx = idx[self.host_id * B:(self.host_id + 1) * B]
        toks = np.stack([
            np.asarray(self.data[i * self.seq_len:(i + 1) * self.seq_len + 1])
            for i in idx])
        return {"tokens": toks[:, :-1].astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def unigram_entropy(ds: SyntheticMarkov, n=50_000):
    """Reference entropy floor of the synthetic stream (nats/token)."""
    b = ds.batch_at(0)["tokens"].reshape(-1)[:n]
    _, counts = np.unique(b, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log(p)).sum())
